# SuperSONIC build entry points.
#
#   make artifacts   — AOT-lower the JAX models to HLO-text artifacts
#                      (the only step that runs Python; see python/compile/aot.py)
#   make build       — release build of the Rust coordinator
#   make test        — tier-1 test suite
#   make bench       — run every bench binary (full durations)
#   make bench-smoke — run every bench binary in short deterministic
#                      smoke mode (SUPERSONIC_SMOKE=1); the CI gate
#   make bench-priority — the priority-lanes ablation only
#   make bench-backend  — the multi-backend heterogeneity ablation only
#   make bench-trace    — the latency-breakdown / SLO-alerting bench only
#   make bench-rpc      — the streaming-RPC acceptance bench only
#   make bench-canary   — the canary-rollout / auto-rollback bench only
#   make bench-federation — the multi-site federation ablation bench only
#   make bench-explain  — the control-plane observability bench only
#   make docs-check  — doc gates only: rustdoc -D warnings + the
#                      doc-sync tests (CONFIG.md schema coverage,
#                      OPERATIONS.md bench coverage, smoke registration)

ARTIFACTS := rust/artifacts

# Every registered bench binary. tests/docs_sync.rs asserts this list
# stays in sync with the [[bench]] entries in rust/Cargo.toml, so a new
# bench cannot ship without joining `bench` and `bench-smoke`.
BENCHES := batcher_ablation fig2_autoscaling fig3_static_vs_dynamic \
	gateway_overhead lb_ablation scale_100_servers trigger_ablation \
	modelmesh_ablation per_model_autoscale warm_load_ablation \
	priority_ablation backend_ablation latency_breakdown rpc_streaming \
	canary_rollout federation_ablation control_plane_observability

.PHONY: artifacts build test bench bench-smoke bench-priority bench-backend bench-trace bench-rpc bench-canary bench-federation bench-explain docs-check

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && for b in $(BENCHES); do cargo bench --bench $$b; done

bench-smoke:
	cd rust && for b in $(BENCHES); do SUPERSONIC_SMOKE=1 cargo bench --bench $$b || exit 1; done

bench-priority:
	cd rust && cargo bench --bench priority_ablation

bench-backend:
	cd rust && cargo bench --bench backend_ablation

bench-trace:
	cd rust && cargo bench --bench latency_breakdown

bench-rpc:
	cd rust && cargo bench --bench rpc_streaming

bench-canary:
	cd rust && cargo bench --bench canary_rollout

bench-federation:
	cd rust && cargo bench --bench federation_ablation

bench-explain:
	cd rust && cargo bench --bench control_plane_observability

docs-check:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cd rust && cargo test -q --test docs_sync
	cd rust && cargo test -q --lib config_doc_covers_every_schema_field
