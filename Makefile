# SuperSONIC build entry points.
#
#   make artifacts   — AOT-lower the JAX models to HLO-text artifacts
#                      (the only step that runs Python; see python/compile/aot.py)
#   make build       — release build of the Rust coordinator
#   make test        — tier-1 test suite
#   make bench       — run every bench binary

ARTIFACTS := rust/artifacts

.PHONY: artifacts build test bench

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && for b in batcher_ablation fig2_autoscaling fig3_static_vs_dynamic \
		gateway_overhead lb_ablation scale_100_servers trigger_ablation \
		modelmesh_ablation per_model_autoscale; do cargo bench --bench $$b; done
