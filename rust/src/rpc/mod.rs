//! `sonic-rpc`: the networked inference protocol (gRPC analogue).
//!
//! SuperSONIC exposes "a single gRPC endpoint for inference requests"
//! (Fig. 1). Reimplementing HTTP/2 + protobuf from scratch is out of scope
//! offline, so this is a compact length-prefixed binary protocol over TCP
//! that preserves the same code path: serialization, socket backpressure,
//! connection reuse, per-request metadata (auth token, trace id,
//! priority class) and a server-side latency breakdown in every response
//! (feeding the §2.3 "breakdown of total request latency by source").
//!
//! Wire format (all integers little-endian):
//!
//! ```text
//!     frame    := u32 payload_len ++ payload            (max 64 MiB)
//!     request  := u8 kind ++ u64 request_id ++ u64 trace_id
//!                 ++ u8 flags ++ str8 token ++ str8 model ++ u8 priority
//!                 ++ u8 ndim ++ ndim*u32 dims ++ bytes32 tensor_data
//!     response := u8 status ++ u64 request_id
//!                 ++ u32 queue_us ++ u32 compute_us ++ u32 batch_size
//!                 ++ (ok? u8 ndim ++ ndim*u32 dims ++ bytes32 data
//!                       : str16 error_message)
//!     str8     := u8 len ++ len bytes (utf-8)
//!     str16    := u16 len ++ len bytes
//!     bytes32  := u32 len ++ len bytes
//! ```
//!
//! The `request_id` is the multiplexing key: a connection may carry many
//! requests concurrently (pipelined frames), and the server answers in
//! completion order — responses are matched back to callers by id, not by
//! position in the stream. Two client types ride this:
//!
//! * [`RpcClient`] — blocking, one request in flight (id checked for
//!   desync); the perf_analyzer model.
//! * [`RpcSession`] — streaming multiplexed session: pipelined writes, a
//!   demultiplexing reader, shared across threads; the gateway's session
//!   pool keeps warm sessions per backend (see `gateway::pool`).
pub mod client;
pub mod codec;
pub mod server;
pub mod session;

pub use client::RpcClient;
pub use codec::{InferRequest, InferResponse, Priority, RequestKind, Status};
pub use server::{RpcServer, RpcServerOpts};
pub use session::{PendingReply, RpcSession, SessionError, SessionOpts};
