//! Wire codec for sonic-rpc (see module docs in `rpc/mod.rs`).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;

/// Hard cap on frame payloads (64 MiB) — protects the server from
/// malformed or hostile length prefixes.
pub const MAX_FRAME: usize = 64 << 20;

/// Request kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Run inference on a tensor.
    Infer = 1,
    /// Liveness/readiness probe.
    Health = 2,
}

/// Request priority classes — Triton's dynamic-batcher priority levels
/// (§2.1). Ordered: `Bulk < Standard < Critical`, so `Ord` compares
/// urgency directly.
///
/// * `Critical` — latency-critical trigger-style inference: served
///   first, never evicted by overload shedding.
/// * `Standard` — the default; the pre-priority behavior.
/// * `Bulk` — offline reprocessing: accumulates freely, sheds first at
///   the gateway gate, and is evicted from a full queue before an
///   incoming higher-priority request is rejected (shed-from-bulk).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Bulk = 0,
    #[default]
    Standard = 1,
    Critical = 2,
}

impl Priority {
    /// Every priority class, lowest first. The config/doc sync tests
    /// iterate this, so adding a lane without documenting it fails.
    pub const ALL: &'static [Priority] =
        &[Priority::Bulk, Priority::Standard, Priority::Critical];

    /// Number of priority classes (the batcher's lane count).
    pub const COUNT: usize = 3;

    /// Lane index (0 = lowest priority).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Canonical config-file / metrics-label name.
    pub fn name(&self) -> &'static str {
        match self {
            Priority::Bulk => "bulk",
            Priority::Standard => "standard",
            Priority::Critical => "critical",
        }
    }

    /// Parse a config-file name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "bulk" => Priority::Bulk,
            "standard" => Priority::Standard,
            "critical" => Priority::Critical,
            other => bail!(
                "unknown priority '{other}' (expected bulk, standard or critical)"
            ),
        })
    }

    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Priority::Bulk,
            1 => Priority::Standard,
            2 => Priority::Critical,
            other => bail!("unknown priority {other}"),
        })
    }
}

impl RequestKind {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => RequestKind::Infer,
            2 => RequestKind::Health,
            other => bail!("unknown request kind {other}"),
        })
    }
}

/// Response status codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok = 0,
    Unauthorized = 1,
    RateLimited = 2,
    Overloaded = 3,
    BadRequest = 4,
    Internal = 5,
    ModelNotFound = 6,
}

impl Status {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Status::Ok,
            1 => Status::Unauthorized,
            2 => Status::RateLimited,
            3 => Status::Overloaded,
            4 => Status::BadRequest,
            5 => Status::Internal,
            6 => Status::ModelNotFound,
            other => bail!("unknown status {other}"),
        })
    }

    /// Human-readable name (metrics label).
    pub fn name(&self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Unauthorized => "unauthorized",
            Status::RateLimited => "rate_limited",
            Status::Overloaded => "overloaded",
            Status::BadRequest => "bad_request",
            Status::Internal => "internal",
            Status::ModelNotFound => "model_not_found",
        }
    }
}

/// An inference (or health) request.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    pub kind: RequestKind,
    pub request_id: u64,
    /// Trace id for distributed tracing (0 = not traced).
    pub trace_id: u64,
    /// Head-sampling decision, made once where the trace id is minted
    /// and honored by every hop: when false, servers record no spans
    /// for this trace even if tracing is enabled.
    pub sampled: bool,
    /// Auth token ("" when auth is disabled).
    pub token: String,
    pub model: String,
    /// Requested priority class. `None` lets the gateway resolve one
    /// from the deployment's `server.priorities` defaults (per token,
    /// then per model, then the global default — `standard` out of the
    /// box).
    pub priority: Option<Priority>,
    pub input: Tensor,
}

impl InferRequest {
    /// Convenience constructor for inference.
    pub fn infer(request_id: u64, model: &str, input: Tensor) -> Self {
        InferRequest {
            kind: RequestKind::Infer,
            request_id,
            trace_id: 0,
            // Sampled-in by default: a non-zero trace id traces unless
            // the head sampler explicitly opted the trace out.
            sampled: true,
            token: String::new(),
            model: model.to_string(),
            priority: None,
            input,
        }
    }

    /// Health probe.
    pub fn health(request_id: u64) -> Self {
        InferRequest {
            kind: RequestKind::Health,
            request_id,
            trace_id: 0,
            sampled: false,
            token: String::new(),
            model: String::new(),
            priority: None,
            input: Tensor::zeros(vec![0]),
        }
    }
}

/// Response with server-side latency breakdown.
#[derive(Clone, Debug, PartialEq)]
pub struct InferResponse {
    pub status: Status,
    pub request_id: u64,
    /// Time spent queued at the server before execution.
    pub queue_us: u32,
    /// Time spent in model execution.
    pub compute_us: u32,
    /// Batch the request was folded into (dynamic batching visibility).
    pub batch_size: u32,
    /// Output tensor (Ok) — zero-dim placeholder otherwise.
    pub output: Tensor,
    /// Error message (non-Ok).
    pub error: String,
}

impl InferResponse {
    /// Successful response.
    pub fn ok(request_id: u64, output: Tensor) -> Self {
        InferResponse {
            status: Status::Ok,
            request_id,
            queue_us: 0,
            compute_us: 0,
            batch_size: 1,
            output,
            error: String::new(),
        }
    }

    /// Error response.
    pub fn err(request_id: u64, status: Status, msg: impl Into<String>) -> Self {
        InferResponse {
            status,
            request_id,
            queue_us: 0,
            compute_us: 0,
            batch_size: 0,
            output: Tensor::zeros(vec![0]),
            error: msg.into(),
        }
    }
}

// ---------------------------------------------------------------------------
// primitives
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated message: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn str8(&mut self) -> Result<String> {
        let n = self.u8()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec()).context("invalid utf-8 in str8")?)
    }

    fn str16(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec()).context("invalid utf-8 in str16")?)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn put_str8(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u8::MAX as usize, "str8 overflow");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let n = bytes.len().min(u16::MAX as usize);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    out.extend_from_slice(&bytes[..n]);
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    let dims = t.shape();
    assert!(dims.len() <= u8::MAX as usize);
    out.push(dims.len() as u8);
    for &d in dims {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    let data = t.to_bytes();
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&data);
}

fn get_tensor(c: &mut Cursor) -> Result<Tensor> {
    let ndim = c.u8()? as usize;
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(c.u32()? as usize);
    }
    let n = c.u32()? as usize;
    if n > MAX_FRAME {
        bail!("tensor payload {n} exceeds frame cap");
    }
    let bytes = c.take(n)?;
    Tensor::from_bytes(dims, bytes)
}

// ---------------------------------------------------------------------------
// message encode/decode
// ---------------------------------------------------------------------------

/// Encode a request payload (without frame header).
pub fn encode_request(req: &InferRequest) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + req.input.len() * 4);
    out.push(req.kind as u8);
    out.extend_from_slice(&req.request_id.to_le_bytes());
    out.extend_from_slice(&req.trace_id.to_le_bytes());
    // Trace flags byte: bit 0 = head-sampling decision.
    out.push(req.sampled as u8);
    put_str8(&mut out, &req.token);
    put_str8(&mut out, &req.model);
    // Priority byte: 0 = unset (gateway resolves a default), else the
    // class shifted by one so `Bulk` is distinguishable from unset.
    out.push(match req.priority {
        None => 0,
        Some(p) => p as u8 + 1,
    });
    put_tensor(&mut out, &req.input);
    out
}

/// Decode a request payload.
pub fn decode_request(buf: &[u8]) -> Result<InferRequest> {
    let mut c = Cursor::new(buf);
    let kind = RequestKind::from_u8(c.u8()?)?;
    let request_id = c.u64()?;
    let trace_id = c.u64()?;
    let flags = c.u8()?;
    if flags & !1 != 0 {
        bail!("unknown trace flags {flags:#04x}");
    }
    let sampled = flags & 1 != 0;
    let token = c.str8()?;
    let model = c.str8()?;
    let priority = match c.u8()? {
        0 => None,
        b => Some(Priority::from_u8(b - 1)?),
    };
    let input = get_tensor(&mut c)?;
    c.done()?;
    Ok(InferRequest { kind, request_id, trace_id, sampled, token, model, priority, input })
}

/// Encode a response payload (without frame header).
pub fn encode_response(resp: &InferResponse) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + resp.output.len() * 4);
    out.push(resp.status as u8);
    out.extend_from_slice(&resp.request_id.to_le_bytes());
    out.extend_from_slice(&resp.queue_us.to_le_bytes());
    out.extend_from_slice(&resp.compute_us.to_le_bytes());
    out.extend_from_slice(&resp.batch_size.to_le_bytes());
    if resp.status == Status::Ok {
        put_tensor(&mut out, &resp.output);
    } else {
        put_str16(&mut out, &resp.error);
    }
    out
}

/// Decode a response payload.
pub fn decode_response(buf: &[u8]) -> Result<InferResponse> {
    let mut c = Cursor::new(buf);
    let status = Status::from_u8(c.u8()?)?;
    let request_id = c.u64()?;
    let queue_us = c.u32()?;
    let compute_us = c.u32()?;
    let batch_size = c.u32()?;
    let (output, error) = if status == Status::Ok {
        (get_tensor(&mut c)?, String::new())
    } else {
        (Tensor::zeros(vec![0]), c.str16()?)
    };
    c.done()?;
    Ok(InferResponse { status, request_id, queue_us, compute_us, batch_size, output, error })
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame of {} bytes exceeds cap", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// streaming encode — the zero-copy tensor path
// ---------------------------------------------------------------------------
//
// `encode_request`/`encode_response` materialize the payload in a fresh
// `Vec` (and `Tensor::to_bytes` a second one) before `write_frame` copies
// it onto the socket. On the hot multiplexed path every hop would pay two
// allocations plus a full copy per tensor. The `write_*_frame` functions
// below stream the frame header and fields straight into the writer,
// converting the borrowed f32 row data in fixed stack-buffer chunks, so a
// routed request moves gateway -> backend with no intermediate payload
// buffer.

/// Wire size of a tensor body: ndim + dims + byte-len + f32 data.
fn tensor_wire_len(t: &Tensor) -> usize {
    1 + 4 * t.shape().len() + 4 + 4 * t.len()
}

/// Exact payload size [`write_request_frame`] streams (equals
/// `encode_request(req).len()`).
pub fn encoded_request_len(req: &InferRequest) -> usize {
    1 + 8
        + 8
        + 1
        + 1
        + req.token.len()
        + 1
        + req.model.len()
        + 1
        + tensor_wire_len(&req.input)
}

/// Exact payload size [`write_response_frame`] streams (equals
/// `encode_response(resp).len()`).
pub fn encoded_response_len(resp: &InferResponse) -> usize {
    let body = if resp.status == Status::Ok {
        tensor_wire_len(&resp.output)
    } else {
        2 + resp.error.len().min(u16::MAX as usize)
    };
    1 + 8 + 4 + 4 + 4 + body
}

fn write_str8<W: Write>(w: &mut W, s: &str) -> Result<()> {
    if s.len() > u8::MAX as usize {
        bail!("str8 overflow: {} bytes", s.len());
    }
    w.write_all(&[s.len() as u8])?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn write_tensor_body<W: Write>(w: &mut W, t: &Tensor) -> Result<()> {
    let dims = t.shape();
    if dims.len() > u8::MAX as usize {
        bail!("tensor rank {} exceeds wire cap", dims.len());
    }
    w.write_all(&[dims.len() as u8])?;
    for &d in dims {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    w.write_all(&((t.len() * 4) as u32).to_le_bytes())?;
    // Chunked conversion from the borrowed row slice: no per-hop Vec.
    let mut buf = [0u8; 4096];
    for chunk in t.data().chunks(buf.len() / 4) {
        let mut n = 0;
        for v in chunk {
            buf[n..n + 4].copy_from_slice(&v.to_le_bytes());
            n += 4;
        }
        w.write_all(&buf[..n])?;
    }
    Ok(())
}

/// Stream one request frame (header + payload) without materializing the
/// payload. `request_id` overrides `req.request_id` on the wire so a
/// multiplexed session can stamp its own id on a borrowed request without
/// cloning it.
pub fn write_request_frame<W: Write>(
    w: &mut W,
    req: &InferRequest,
    request_id: u64,
) -> Result<()> {
    // Validate everything fallible before the first byte goes out: a
    // mid-frame encode error would desync the whole multiplexed stream.
    if req.token.len() > u8::MAX as usize || req.model.len() > u8::MAX as usize {
        bail!("str8 overflow: token/model exceeds 255 bytes");
    }
    if req.input.shape().len() > u8::MAX as usize {
        bail!("tensor rank {} exceeds wire cap", req.input.shape().len());
    }
    let len = encoded_request_len(req);
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds cap");
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[req.kind as u8])?;
    w.write_all(&request_id.to_le_bytes())?;
    w.write_all(&req.trace_id.to_le_bytes())?;
    w.write_all(&[req.sampled as u8])?;
    write_str8(w, &req.token)?;
    write_str8(w, &req.model)?;
    w.write_all(&[match req.priority {
        None => 0,
        Some(p) => p as u8 + 1,
    }])?;
    write_tensor_body(w, &req.input)?;
    w.flush()?;
    Ok(())
}

/// Stream one response frame (header + payload) without materializing the
/// payload — the server-side half of the zero-copy path.
pub fn write_response_frame<W: Write>(w: &mut W, resp: &InferResponse) -> Result<()> {
    if resp.output.shape().len() > u8::MAX as usize {
        bail!("tensor rank {} exceeds wire cap", resp.output.shape().len());
    }
    let len = encoded_response_len(resp);
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds cap");
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[resp.status as u8])?;
    w.write_all(&resp.request_id.to_le_bytes())?;
    w.write_all(&resp.queue_us.to_le_bytes())?;
    w.write_all(&resp.compute_us.to_le_bytes())?;
    w.write_all(&resp.batch_size.to_le_bytes())?;
    if resp.status == Status::Ok {
        write_tensor_body(w, &resp.output)?;
    } else {
        let bytes = resp.error.as_bytes();
        let n = bytes.len().min(u16::MAX as usize);
        w.write_all(&(n as u16).to_le_bytes())?;
        w.write_all(&bytes[..n])?;
    }
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame. Returns None on clean EOF at a frame
/// boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("incoming frame of {len} bytes exceeds cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame body")?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tensor() -> Tensor {
        Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn request_roundtrip() {
        let mut req = InferRequest::infer(42, "particlenet", sample_tensor());
        req.token = "secret-token".into();
        req.trace_id = 7;
        let buf = encode_request(&req);
        let got = decode_request(&buf).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn priority_roundtrips_all_classes() {
        // None (unset) and every explicit class survive the wire.
        let mut req = InferRequest::infer(1, "m", sample_tensor());
        assert_eq!(decode_request(&encode_request(&req)).unwrap().priority, None);
        for &p in Priority::ALL {
            req.priority = Some(p);
            let got = decode_request(&encode_request(&req)).unwrap();
            assert_eq!(got.priority, Some(p), "class {p:?}");
            assert_eq!(got, req);
        }
    }

    #[test]
    fn bad_priority_byte_rejected() {
        let req = InferRequest::infer(1, "m", sample_tensor());
        let mut buf = encode_request(&req);
        // kind(1) + request_id(8) + trace_id(8) + flags(1) + token("",1)
        // + model("m",2)
        let prio_off = 1 + 8 + 8 + 1 + 1 + 2;
        assert_eq!(buf[prio_off], 0, "unset priority encodes as 0");
        buf[prio_off] = 9;
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn sampling_bit_roundtrips_and_unknown_flags_rejected() {
        let mut req = InferRequest::infer(3, "m", sample_tensor());
        req.trace_id = 11;
        req.sampled = false;
        let got = decode_request(&encode_request(&req)).unwrap();
        assert!(!got.sampled);
        assert_eq!(got, req);
        req.sampled = true;
        assert!(decode_request(&encode_request(&req)).unwrap().sampled);
        // flags byte sits right after kind + request_id + trace_id
        let mut buf = encode_request(&req);
        buf[1 + 8 + 8] = 0x82;
        assert!(decode_request(&buf).is_err(), "unknown flag bits must be rejected");
    }

    #[test]
    fn priority_names_and_order() {
        for &p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
        assert!(Priority::Bulk < Priority::Standard);
        assert!(Priority::Standard < Priority::Critical);
        assert_eq!(Priority::ALL.len(), Priority::COUNT);
        assert_eq!(Priority::default(), Priority::Standard);
    }

    #[test]
    fn health_roundtrip() {
        let req = InferRequest::health(1);
        let got = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(got.kind, RequestKind::Health);
    }

    #[test]
    fn response_ok_roundtrip() {
        let mut resp = InferResponse::ok(42, sample_tensor());
        resp.queue_us = 1500;
        resp.compute_us = 3200;
        resp.batch_size = 8;
        let got = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn response_err_roundtrip() {
        let resp = InferResponse::err(9, Status::RateLimited, "slow down");
        let got = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(got.status, Status::RateLimited);
        assert_eq!(got.error, "slow down");
    }

    #[test]
    fn truncated_rejected() {
        let req = InferRequest::infer(1, "m", sample_tensor());
        let buf = encode_request(&req);
        assert!(decode_request(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let req = InferRequest::infer(1, "m", sample_tensor());
        let mut buf = encode_request(&req);
        buf.push(0);
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn bad_kind_rejected() {
        let req = InferRequest::infer(1, "m", sample_tensor());
        let mut buf = encode_request(&req);
        buf[0] = 99;
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"world!").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"world!");
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn streaming_request_frame_matches_buffered_encoding() {
        let mut req = InferRequest::infer(7, "particlenet", sample_tensor());
        req.token = "tok".into();
        req.trace_id = 9;
        req.sampled = false;
        req.priority = Some(Priority::Critical);
        let mut framed = Vec::new();
        write_request_frame(&mut framed, &req, 123).unwrap();
        let mut expected = req.clone();
        expected.request_id = 123;
        let payload = encode_request(&expected);
        assert_eq!(encoded_request_len(&req), payload.len());
        let mut want = (payload.len() as u32).to_le_bytes().to_vec();
        want.extend_from_slice(&payload);
        assert_eq!(framed, want);
        // and it decodes back with the overridden id
        let mut r = &framed[..];
        let frame = read_frame(&mut r).unwrap().unwrap();
        let got = decode_request(&frame).unwrap();
        assert_eq!(got, expected);
    }

    #[test]
    fn streaming_response_frame_matches_buffered_encoding() {
        let mut ok = InferResponse::ok(42, sample_tensor());
        ok.queue_us = 11;
        ok.compute_us = 22;
        ok.batch_size = 8;
        let err = InferResponse::err(9, Status::Overloaded, "queue full");
        for resp in [ok, err] {
            let mut framed = Vec::new();
            write_response_frame(&mut framed, &resp).unwrap();
            let payload = encode_response(&resp);
            assert_eq!(encoded_response_len(&resp), payload.len());
            let mut want = (payload.len() as u32).to_le_bytes().to_vec();
            want.extend_from_slice(&payload);
            assert_eq!(framed, want, "status {:?}", resp.status);
            let mut r = &framed[..];
            let frame = read_frame(&mut r).unwrap().unwrap();
            assert_eq!(decode_response(&frame).unwrap(), resp);
        }
    }

    #[test]
    fn streaming_encode_rejects_oversized_token() {
        let mut req = InferRequest::infer(1, "m", sample_tensor());
        req.token = "x".repeat(300);
        let mut out = Vec::new();
        assert!(write_request_frame(&mut out, &req, 1).is_err());
    }

    #[test]
    fn status_names() {
        assert_eq!(Status::Ok.name(), "ok");
        assert_eq!(Status::Overloaded.name(), "overloaded");
    }
}
