//! Blocking RPC client with connection reuse and auth/trace metadata.
//!
//! One [`RpcClient`] wraps one TCP connection and issues requests
//! sequentially (the perf_analyzer model: N concurrent clients = N
//! connections). Request ids are assigned from a process-wide counter and
//! verified against responses to catch desync bugs early.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::codec::{self, InferRequest, InferResponse, Priority, RequestKind, Status};
use crate::runtime::Tensor;

static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// Blocking sonic-rpc client over one TCP connection.
pub struct RpcClient {
    stream: TcpStream,
    /// Auth token attached to every request.
    pub token: String,
    /// Trace id attached to every request (0 = untraced).
    pub trace_id: u64,
    /// Head-sampling bit attached to every request. Defaults to true:
    /// a non-zero `trace_id` traces unless the sampler opted it out
    /// (see `Tracer::start_trace`).
    pub sampled: bool,
    /// Priority class attached to every request (`None` lets the
    /// gateway resolve the deployment's configured default).
    pub priority: Option<Priority>,
}

impl RpcClient {
    /// Connect to `addr` ("host:port").
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            token: String::new(),
            trace_id: 0,
            sampled: true,
            priority: None,
        })
    }

    /// Connect with a timeout.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        let sockaddr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("parsing address {addr}"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            token: String::new(),
            trace_id: 0,
            sampled: true,
            priority: None,
        })
    }

    /// Set the auth token used for subsequent requests.
    pub fn with_token(mut self, token: &str) -> Self {
        self.token = token.to_string();
        self
    }

    /// Set the priority class used for subsequent requests.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = Some(priority);
        self
    }

    /// Set the propagated trace context (id + head-sampling decision)
    /// for subsequent requests.
    pub fn with_trace(mut self, trace_id: u64, sampled: bool) -> Self {
        self.trace_id = trace_id;
        self.sampled = sampled;
        self
    }

    /// Issue an inference request and wait for the response.
    pub fn infer(&mut self, model: &str, input: Tensor) -> Result<InferResponse> {
        let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let req = InferRequest {
            kind: RequestKind::Infer,
            request_id,
            trace_id: self.trace_id,
            sampled: self.sampled,
            token: self.token.clone(),
            model: model.to_string(),
            priority: self.priority,
            input,
        };
        self.call(req)
    }

    /// [`RpcClient::infer`] with an explicit one-off priority class.
    pub fn infer_prio(
        &mut self,
        model: &str,
        input: Tensor,
        priority: Priority,
    ) -> Result<InferResponse> {
        let prev = self.priority;
        self.priority = Some(priority);
        let out = self.infer(model, input);
        self.priority = prev;
        out
    }

    /// Issue a health probe; Ok(true) if the endpoint answers Ok.
    pub fn health(&mut self) -> Result<bool> {
        let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let mut req = InferRequest::health(request_id);
        req.token = self.token.clone();
        Ok(self.call(req)?.status == Status::Ok)
    }

    /// Send a raw request and match the response id.
    pub fn call(&mut self, req: InferRequest) -> Result<InferResponse> {
        codec::write_frame(&mut self.stream, &codec::encode_request(&req))?;
        let frame = codec::read_frame(&mut self.stream)?
            .context("connection closed while awaiting response")?;
        let resp = codec::decode_response(&frame)?;
        // request_id 0 is the server's "could not even parse" escape hatch
        if resp.request_id != 0 && resp.request_id != req.request_id {
            bail!(
                "response id {} does not match request id {} (protocol desync)",
                resp.request_id,
                req.request_id
            );
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    // Client/server integration tests live in rpc::server::tests (they
    // need both halves); here we only test id assignment.
    use super::*;

    #[test]
    fn request_ids_unique() {
        let a = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let b = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        assert_ne!(a, b);
    }
}
