//! Blocking RPC client with connection reuse and auth/trace metadata.
//!
//! One [`RpcClient`] wraps one TCP connection and issues requests
//! sequentially (the perf_analyzer model: N concurrent clients = N
//! connections). Request ids are assigned from a process-wide counter and
//! verified against responses to catch desync bugs early.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::codec::{self, InferRequest, InferResponse, Priority, RequestKind, Status};
use crate::runtime::Tensor;

static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// Blocking sonic-rpc client over one TCP connection.
pub struct RpcClient {
    stream: TcpStream,
    /// Auth token attached to every request.
    pub token: String,
    /// Trace id attached to every request (0 = untraced).
    pub trace_id: u64,
    /// Head-sampling bit attached to every request. Defaults to true:
    /// a non-zero `trace_id` traces unless the sampler opted it out
    /// (see `Tracer::start_trace`).
    pub sampled: bool,
    /// Priority class attached to every request (`None` lets the
    /// gateway resolve the deployment's configured default).
    pub priority: Option<Priority>,
    /// Set once an io error may have left a partial frame on the stream;
    /// further calls would read garbage, so they are refused.
    desynced: bool,
}

impl RpcClient {
    /// Connect to `addr` ("host:port").
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            token: String::new(),
            trace_id: 0,
            sampled: true,
            priority: None,
            desynced: false,
        })
    }

    /// Connect with a timeout.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        let sockaddr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("parsing address {addr}"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            token: String::new(),
            trace_id: 0,
            sampled: true,
            priority: None,
            desynced: false,
        })
    }

    /// Bound every subsequent read/write on the connection: a hung
    /// backend surfaces as an io error after `timeout` instead of
    /// blocking the caller forever. After a timeout the stream may hold
    /// a partial frame, so the client refuses further calls — reconnect
    /// (the gateway's session pool does this by evicting the session).
    pub fn with_io_timeout(self, timeout: Duration) -> Result<Self> {
        self.stream.set_read_timeout(Some(timeout))?;
        self.stream.set_write_timeout(Some(timeout))?;
        Ok(self)
    }

    /// Set the auth token used for subsequent requests.
    pub fn with_token(mut self, token: &str) -> Self {
        self.token = token.to_string();
        self
    }

    /// Set the priority class used for subsequent requests.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = Some(priority);
        self
    }

    /// Set the propagated trace context (id + head-sampling decision)
    /// for subsequent requests.
    pub fn with_trace(mut self, trace_id: u64, sampled: bool) -> Self {
        self.trace_id = trace_id;
        self.sampled = sampled;
        self
    }

    /// Issue an inference request and wait for the response.
    pub fn infer(&mut self, model: &str, input: Tensor) -> Result<InferResponse> {
        let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let req = InferRequest {
            kind: RequestKind::Infer,
            request_id,
            trace_id: self.trace_id,
            sampled: self.sampled,
            token: self.token.clone(),
            model: model.to_string(),
            priority: self.priority,
            input,
        };
        self.call(req)
    }

    /// [`RpcClient::infer`] with an explicit one-off priority class.
    pub fn infer_prio(
        &mut self,
        model: &str,
        input: Tensor,
        priority: Priority,
    ) -> Result<InferResponse> {
        let prev = self.priority;
        self.priority = Some(priority);
        let out = self.infer(model, input);
        self.priority = prev;
        out
    }

    /// Issue a health probe; Ok(true) if the endpoint answers Ok.
    pub fn health(&mut self) -> Result<bool> {
        let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let mut req = InferRequest::health(request_id);
        req.token = self.token.clone();
        Ok(self.call(req)?.status == Status::Ok)
    }

    /// Send a raw request and match the response id.
    pub fn call(&mut self, req: InferRequest) -> Result<InferResponse> {
        if self.desynced {
            bail!("connection desynced by an earlier io timeout; reconnect");
        }
        // Streaming encode: the tensor payload goes out from the borrowed
        // slice, no intermediate Vec (see codec::write_request_frame).
        if let Err(e) = codec::write_request_frame(&mut self.stream, &req, req.request_id) {
            self.desynced = true;
            return Err(annotate_io_timeout(e).context("writing request"));
        }
        let frame = match codec::read_frame(&mut self.stream) {
            Ok(Some(f)) => f,
            Ok(None) => {
                self.desynced = true;
                bail!("connection closed while awaiting response");
            }
            Err(e) => {
                self.desynced = true;
                return Err(annotate_io_timeout(e).context("awaiting response"));
            }
        };
        let resp = codec::decode_response(&frame)?;
        // request_id 0 is the server's "could not even parse" escape hatch
        if resp.request_id != 0 && resp.request_id != req.request_id {
            bail!(
                "response id {} does not match request id {} (protocol desync)",
                resp.request_id,
                req.request_id
            );
        }
        Ok(resp)
    }
}

/// Wrap WouldBlock/TimedOut io errors with an explicit "io timeout"
/// message so callers (and the gateway) can tell a hung backend from a
/// protocol failure.
fn annotate_io_timeout(e: anyhow::Error) -> anyhow::Error {
    let timed_out = e
        .downcast_ref::<std::io::Error>()
        .is_some_and(|ioe| {
            matches!(
                ioe.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        });
    if timed_out {
        e.context("rpc io timeout")
    } else {
        e
    }
}

#[cfg(test)]
mod tests {
    // Client/server integration tests live in rpc::server::tests (they
    // need both halves); here we only test id assignment and timeout
    // plumbing (which needs no server at all — just a silent listener).
    use super::*;

    #[test]
    fn request_ids_unique() {
        let a = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let b = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        assert_ne!(a, b);
    }

    #[test]
    fn io_timeout_unblocks_hung_backend_and_poisons_client() {
        // Regression: before with_io_timeout existed, a backend that
        // accepted the connection but never answered blocked infer()
        // forever. Bind a listener that accepts and stays silent.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let keeper = std::thread::spawn(move || listener.accept().map(|(s, _)| s));

        let mut client = RpcClient::connect(&addr)
            .unwrap()
            .with_io_timeout(Duration::from_millis(200))
            .unwrap();
        let t0 = std::time::Instant::now();
        let err = client.infer("m", Tensor::zeros(vec![1])).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "timeout did not fire");
        assert!(format!("{err:#}").contains("io timeout"), "got: {err:#}");
        // The stream may hold a partial exchange now: refuse reuse.
        let err2 = client.infer("m", Tensor::zeros(vec![1])).unwrap_err();
        assert!(format!("{err2:#}").contains("desynced"), "got: {err2:#}");
        drop(keeper);
    }
}
