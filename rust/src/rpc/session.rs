//! Streaming multiplexed RPC session: many requests in flight on one
//! TCP connection.
//!
//! The blocking [`RpcClient`](super::RpcClient) is one-request-per-exchange:
//! it writes a frame, then blocks until the matching response frame comes
//! back, so a connection is idle for a full round trip per request. Real
//! SuperSONIC deployments speak gRPC streams through Envoy — the client
//! keeps the pipe full and the server answers in whatever order batching
//! finishes. [`RpcSession`] is that model for sonic-rpc:
//!
//! * **Pipelined writes** — [`RpcSession::submit`] stamps a session-local
//!   request id, streams the frame (zero-copy tensor path, see
//!   `codec::write_request_frame`), and returns a [`PendingReply`]
//!   immediately; callers fan out as many submits as they like.
//! * **Demultiplexing reader** — one background thread reads response
//!   frames and matches them to waiting callers by request id, so
//!   responses may arrive in any order (the server executes concurrently).
//! * **Per-request deadlines** — an optional io timeout bounds how long a
//!   caller waits; an expired request fails with [`SessionError::Timeout`]
//!   while the session itself stays usable (the late response, if it ever
//!   lands, is discarded).
//!
//! A session is `Sync`: the gateway's session pool shares one `Arc<RpcSession>`
//! across request threads.

use std::collections::HashMap;
use std::io::BufWriter;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::codec::{self, InferRequest, InferResponse};
use crate::runtime::Tensor;

/// Distinguishable session failures — the gateway maps these onto
/// retryable statuses (a timed-out or dead backend hop becomes
/// `Overloaded`, letting the router retry a different replica).
#[derive(Debug, thiserror::Error)]
pub enum SessionError {
    /// No response within the configured io timeout.
    #[error("rpc io timeout after {0:?}")]
    Timeout(Duration),
    /// The connection died (EOF, reset, or a poisoned write).
    #[error("rpc session closed: {0}")]
    Closed(String),
}

/// Tuning for a session; `Default` gives no timeouts (wait forever).
#[derive(Clone, Debug, Default)]
pub struct SessionOpts {
    /// TCP connect timeout (`None` = OS default).
    pub connect_timeout: Option<Duration>,
    /// Per-request deadline from submit to matched response.
    pub io_timeout: Option<Duration>,
}

struct PendingEntry {
    tx: mpsc::Sender<Result<InferResponse, SessionError>>,
    deadline: Option<Instant>,
}

struct SessionInner {
    writer: Mutex<BufWriter<TcpStream>>,
    pending: Mutex<HashMap<u64, PendingEntry>>,
    next_id: AtomicU64,
    closed: AtomicBool,
    io_timeout: Option<Duration>,
    /// Unmatched response frames seen by the reader (late responses after
    /// a timeout, or a server desync) — exposed for tests/metrics.
    orphans: AtomicU64,
}

impl SessionInner {
    /// Fail every waiter and mark the session dead.
    fn poison(&self, why: &str) {
        self.closed.store(true, Ordering::SeqCst);
        let mut pending = self.pending.lock().unwrap();
        for (_, entry) in pending.drain() {
            let _ = entry.tx.send(Err(SessionError::Closed(why.to_string())));
        }
    }
}

/// A multiplexed sonic-rpc session over one TCP connection.
pub struct RpcSession {
    inner: Arc<SessionInner>,
    stream: TcpStream,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Handle to one in-flight request; consume with [`PendingReply::wait`].
pub struct PendingReply {
    rx: mpsc::Receiver<Result<InferResponse, SessionError>>,
    request_id: u64,
}

impl PendingReply {
    /// The wire id the session stamped on the request.
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// Block until the matched response arrives (or the deadline/session
    /// failure surfaces as [`SessionError`]).
    pub fn wait(self) -> Result<InferResponse> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(e.into()),
            // The sender half only drops with the session torn down.
            Err(_) => Err(SessionError::Closed("session dropped".into()).into()),
        }
    }
}

impl RpcSession {
    /// Connect a session to `addr` ("host:port").
    pub fn connect(addr: &str, opts: SessionOpts) -> Result<Self> {
        let stream = match opts.connect_timeout {
            Some(t) => {
                let sockaddr: std::net::SocketAddr =
                    addr.parse().with_context(|| format!("parsing address {addr}"))?;
                TcpStream::connect_timeout(&sockaddr, t)
                    .with_context(|| format!("connecting session to {addr}"))?
            }
            None => TcpStream::connect(addr)
                .with_context(|| format!("connecting session to {addr}"))?,
        };
        stream.set_nodelay(true)?;

        let inner = Arc::new(SessionInner {
            writer: Mutex::new(BufWriter::new(stream.try_clone()?)),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            closed: AtomicBool::new(false),
            io_timeout: opts.io_timeout,
            orphans: AtomicU64::new(0),
        });

        let reader_stream = stream.try_clone()?;
        // Short poll so the reader notices shutdown and sweeps deadlines
        // even while the socket is quiet.
        reader_stream.set_read_timeout(Some(Duration::from_millis(50)))?;
        let inner2 = Arc::clone(&inner);
        let reader = std::thread::Builder::new()
            .name("rpc-session-reader".into())
            .spawn(move || reader_loop(reader_stream, inner2))
            .expect("spawning session reader");

        Ok(RpcSession { inner, stream, reader: Mutex::new(Some(reader)) })
    }

    /// Pipeline one request: stamp a session-local request id, stream the
    /// frame, and return immediately with a [`PendingReply`]. The caller
    /// keeps ownership of `req` (and its tensor) — on a transport error
    /// the same request can be retried on another session without a clone.
    pub fn submit(&self, req: &InferRequest) -> Result<PendingReply> {
        if self.is_closed() {
            bail!(SessionError::Closed("session already closed".into()));
        }
        let request_id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let deadline = self.inner.io_timeout.map(|t| Instant::now() + t);
        self.inner
            .pending
            .lock()
            .unwrap()
            .insert(request_id, PendingEntry { tx, deadline });

        let write_result = {
            let mut w = self.inner.writer.lock().unwrap();
            codec::write_request_frame(&mut *w, req, request_id)
        };
        if let Err(e) = write_result {
            self.inner.pending.lock().unwrap().remove(&request_id);
            // A partial frame poisons the byte stream for everyone.
            self.inner.poison(&format!("write failed: {e}"));
            return Err(e.context("writing pipelined request"));
        }
        Ok(PendingReply { rx, request_id })
    }

    /// Submit and block for the matched response.
    pub fn call(&self, req: &InferRequest) -> Result<InferResponse> {
        self.submit(req)?.wait()
    }

    /// Convenience inference call with default metadata (no token/trace,
    /// gateway-resolved priority). For per-request metadata build an
    /// [`InferRequest`] and use [`RpcSession::submit`]/[`RpcSession::call`].
    pub fn infer(&self, model: &str, input: Tensor) -> Result<InferResponse> {
        self.call(&InferRequest::infer(0, model, input))
    }

    /// Requests currently awaiting responses.
    pub fn in_flight(&self) -> usize {
        self.inner.pending.lock().unwrap().len()
    }

    /// True once the transport died or the session was shut down; a
    /// closed session fails all submits and should be discarded.
    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::SeqCst)
    }

    /// Responses that matched no waiting request (late after timeout).
    pub fn orphan_responses(&self) -> u64 {
        self.inner.orphans.load(Ordering::SeqCst)
    }

    /// Close the transport and join the reader; pending requests fail
    /// with [`SessionError::Closed`].
    pub fn shutdown(&self) {
        self.inner.poison("session shut down");
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for RpcSession {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reader_loop(mut stream: TcpStream, inner: Arc<SessionInner>) {
    loop {
        if inner.closed.load(Ordering::SeqCst) {
            return;
        }
        match codec::read_frame(&mut stream) {
            Ok(Some(frame)) => match codec::decode_response(&frame) {
                Ok(resp) => {
                    let entry = inner.pending.lock().unwrap().remove(&resp.request_id);
                    match entry {
                        Some(e) => {
                            let _ = e.tx.send(Ok(resp));
                        }
                        None => {
                            inner.orphans.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
                Err(e) => {
                    // Undecodable response: framing may still be intact,
                    // but the caller it belonged to can never be matched.
                    // Treat as a protocol failure and poison.
                    inner.poison(&format!("undecodable response: {e}"));
                    return;
                }
            },
            Ok(None) => {
                inner.poison("connection closed by peer");
                return;
            }
            Err(e) => {
                let timeout_tick = e
                    .downcast_ref::<std::io::Error>()
                    .map(|ioe| {
                        matches!(
                            ioe.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        )
                    })
                    .unwrap_or(false);
                if !timeout_tick {
                    inner.poison(&format!("read failed: {e}"));
                    return;
                }
            }
        }
        sweep_deadlines(&inner);
    }
}

/// Fail requests whose deadline passed; the session stays open.
fn sweep_deadlines(inner: &SessionInner) {
    let now = Instant::now();
    let timeout = match inner.io_timeout {
        Some(t) => t,
        None => return,
    };
    let mut pending = inner.pending.lock().unwrap();
    let expired: Vec<u64> = pending
        .iter()
        .filter(|(_, e)| e.deadline.is_some_and(|d| d <= now))
        .map(|(&id, _)| id)
        .collect();
    for id in expired {
        if let Some(e) = pending.remove(&id) {
            let _ = e.tx.send(Err(SessionError::Timeout(timeout)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::codec::{RequestKind, Status};
    use crate::rpc::server::{Handler, RpcServer, RpcServerOpts};

    fn echo_handler() -> Handler {
        Arc::new(|req: InferRequest| match req.kind {
            RequestKind::Health => InferResponse::ok(req.request_id, Tensor::zeros(vec![0])),
            RequestKind::Infer => InferResponse::ok(req.request_id, req.input),
        })
    }

    fn demux_server(handler: Handler) -> RpcServer {
        RpcServer::start_with_opts(
            "127.0.0.1:0",
            RpcServerOpts { workers: 2, dispatch_threads: 8, ..Default::default() },
            handler,
        )
        .unwrap()
    }

    #[test]
    fn pipelined_requests_match_their_responses() {
        let server = demux_server(echo_handler());
        let session =
            RpcSession::connect(&server.addr().to_string(), SessionOpts::default()).unwrap();
        let mut replies = Vec::new();
        for i in 0..32 {
            let req =
                InferRequest::infer(0, "m", Tensor::new(vec![1], vec![i as f32]).unwrap());
            replies.push((i, session.submit(&req).unwrap()));
        }
        for (i, reply) in replies {
            let resp = reply.wait().unwrap();
            assert_eq!(resp.status, Status::Ok);
            assert_eq!(resp.output.data(), &[i as f32], "response matched wrong request");
        }
        assert_eq!(session.in_flight(), 0);
    }

    #[test]
    fn out_of_order_responses_demultiplex() {
        // Server delays inversely to the payload: first-submitted finishes
        // last, so responses come back in reverse order.
        let handler: Handler = Arc::new(|req: InferRequest| {
            let v = req.input.data()[0];
            std::thread::sleep(Duration::from_millis((40.0 - 10.0 * v) as u64));
            InferResponse::ok(req.request_id, req.input)
        });
        let server = demux_server(handler);
        let session =
            RpcSession::connect(&server.addr().to_string(), SessionOpts::default()).unwrap();
        let replies: Vec<_> = (0..4)
            .map(|i| {
                let req =
                    InferRequest::infer(0, "m", Tensor::new(vec![1], vec![i as f32]).unwrap());
                (i, session.submit(&req).unwrap())
            })
            .collect();
        for (i, reply) in replies {
            assert_eq!(reply.wait().unwrap().output.data(), &[i as f32]);
        }
    }

    #[test]
    fn shared_across_threads() {
        let server = demux_server(echo_handler());
        let session = Arc::new(
            RpcSession::connect(&server.addr().to_string(), SessionOpts::default()).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..8 {
            let session = Arc::clone(&session);
            handles.push(std::thread::spawn(move || {
                for i in 0..16 {
                    let v = (t * 1000 + i) as f32;
                    let req =
                        InferRequest::infer(0, "m", Tensor::new(vec![1], vec![v]).unwrap());
                    let resp = session.call(&req).unwrap();
                    assert_eq!(resp.output.data(), &[v], "cross-talk between threads");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn io_timeout_fails_request_but_session_survives() {
        // A handler that never answers one specific request.
        let handler: Handler = Arc::new(|req: InferRequest| {
            if req.input.data().first() == Some(&-1.0) {
                std::thread::sleep(Duration::from_secs(3600));
            }
            InferResponse::ok(req.request_id, req.input)
        });
        let server = demux_server(handler);
        let session = RpcSession::connect(
            &server.addr().to_string(),
            SessionOpts { io_timeout: Some(Duration::from_millis(200)), ..Default::default() },
        )
        .unwrap();
        let hung =
            InferRequest::infer(0, "m", Tensor::new(vec![1], vec![-1.0]).unwrap());
        let reply = session.submit(&hung).unwrap();
        let err = reply.wait().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SessionError>(), Some(SessionError::Timeout(_))),
            "expected Timeout, got {err}"
        );
        // Session is still usable for well-behaved requests.
        assert!(!session.is_closed());
        let ok = InferRequest::infer(0, "m", Tensor::new(vec![1], vec![5.0]).unwrap());
        assert_eq!(session.call(&ok).unwrap().output.data(), &[5.0]);
    }

    #[test]
    fn peer_close_fails_pending_and_closes_session() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accepter = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(100));
            drop(stream); // close without answering
        });
        let session = RpcSession::connect(&addr, SessionOpts::default()).unwrap();
        let req = InferRequest::infer(0, "m", Tensor::new(vec![1], vec![1.0]).unwrap());
        let reply = session.submit(&req).unwrap();
        let err = reply.wait().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SessionError>(), Some(SessionError::Closed(_))),
            "expected Closed, got {err}"
        );
        assert!(session.is_closed());
        assert!(session.submit(&req).is_err(), "closed session must refuse submits");
        accepter.join().unwrap();
    }
}
