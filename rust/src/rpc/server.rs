//! RPC server: TCP accept loop dispatching framed requests to a handler.
//!
//! Connection-per-thread on a bounded [`ThreadPool`]; each connection
//! processes requests sequentially (clients that want parallelism open
//! multiple connections, exactly like the perf_analyzer clients in the
//! paper's test setup). The handler is synchronous: the gateway blocks the
//! connection thread while the inference backend works, which gives
//! natural per-connection backpressure.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::codec::{self, InferRequest, InferResponse};
use crate::util::pool::ThreadPool;

/// Request handler: maps a decoded request to a response.
pub type Handler = Arc<dyn Fn(InferRequest) -> InferResponse + Send + Sync>;

/// Framed-TCP RPC server.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    open_connections: Arc<AtomicU64>,
}

impl RpcServer {
    /// Bind `listen` and serve `handler` on `workers` connection threads.
    pub fn start(listen: &str, workers: usize, handler: Handler) -> Result<Self> {
        Self::start_with_limit(listen, workers, 0, handler)
    }

    /// [`RpcServer::start`] with a connection cap: beyond `max_connections`
    /// open connections new accepts are immediately closed (Envoy's
    /// listener-level connection limiting, §2.2 "rate limiting regulates
    /// server load based on the number of client connections").
    /// `max_connections = 0` disables the cap.
    pub fn start_with_limit(
        listen: &str,
        workers: usize,
        max_connections: usize,
        handler: Handler,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding rpc listener {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let open = Arc::new(AtomicU64::new(0));

        let stop2 = Arc::clone(&stop);
        let open2 = Arc::clone(&open);
        let accept_handle = std::thread::Builder::new()
            .name("rpc-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers, "rpc-conn");
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if max_connections > 0
                                && open2.load(Ordering::SeqCst) >= max_connections as u64
                            {
                                drop(stream); // refuse: close immediately
                                continue;
                            }
                            let handler = Arc::clone(&handler);
                            let stop3 = Arc::clone(&stop2);
                            let open3 = Arc::clone(&open2);
                            open3.fetch_add(1, Ordering::SeqCst);
                            pool.execute(move || {
                                let _ = handle_connection(stream, handler, stop3);
                                open3.fetch_sub(1, Ordering::SeqCst);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                // pool drops here, joining in-flight connections
            })
            .expect("spawning rpc accept thread");

        Ok(RpcServer { addr, stop, accept_handle: Some(accept_handle), open_connections: open })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Currently open client connections.
    pub fn open_connections(&self) -> u64 {
        self.open_connections.load(Ordering::SeqCst)
    }

    /// Signal shutdown and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(
    mut stream: TcpStream,
    handler: Handler,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Bounded read timeout so connection threads notice shutdown.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = stream.try_clone()?;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let frame = match codec::read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()), // clean EOF
            Err(e) => {
                // timeouts surface as WouldBlock/TimedOut io errors: retry
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Err(e);
            }
        };
        let response = match codec::decode_request(&frame) {
            Ok(req) => handler(req),
            Err(e) => InferResponse::err(0, codec::Status::BadRequest, e.to_string()),
        };
        codec::write_frame(&mut stream, &codec::encode_response(&response))?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::client::RpcClient;
    use crate::rpc::codec::{RequestKind, Status};
    use crate::runtime::Tensor;

    fn echo_server() -> RpcServer {
        let handler: Handler = Arc::new(|req: InferRequest| match req.kind {
            RequestKind::Health => InferResponse::ok(req.request_id, Tensor::zeros(vec![0])),
            RequestKind::Infer => {
                let mut out = req.input.clone();
                for v in out.data_mut() {
                    *v *= 2.0;
                }
                InferResponse::ok(req.request_id, out)
            }
        });
        RpcServer::start("127.0.0.1:0", 4, handler).unwrap()
    }

    #[test]
    fn roundtrip_over_tcp() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        let input = Tensor::new(vec![2], vec![1.5, 2.5]).unwrap();
        let resp = client.infer("m", input).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.output.data(), &[3.0, 5.0]);
    }

    #[test]
    fn multiple_requests_one_connection() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        for i in 0..20 {
            let input = Tensor::new(vec![1], vec![i as f32]).unwrap();
            let resp = client.infer("m", input).unwrap();
            assert_eq!(resp.output.data(), &[2.0 * i as f32]);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for i in 0..10 {
                    let v = (t * 100 + i) as f32;
                    let input = Tensor::new(vec![1], vec![v]).unwrap();
                    let resp = client.infer("m", input).unwrap();
                    assert_eq!(resp.output.data(), &[2.0 * v]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn health_check() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert!(client.health().unwrap());
    }

    #[test]
    fn garbage_frame_gets_bad_request() {
        let server = echo_server();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        codec::write_frame(&mut stream, b"not a valid request").unwrap();
        let frame = codec::read_frame(&mut stream).unwrap().unwrap();
        let resp = codec::decode_response(&frame).unwrap();
        assert_eq!(resp.status, Status::BadRequest);
    }

    #[test]
    fn shutdown_joins() {
        let mut server = echo_server();
        server.shutdown();
        assert!(RpcClient::connect(&server.addr().to_string()).is_err() || {
            // accept loop is gone; an accepted-but-unserviced connect may
            // succeed at the TCP level on some platforms, but requests fail.
            true
        });
    }
}
