//! RPC server: TCP accept loop dispatching framed requests to a handler.
//!
//! Two dispatch modes share the accept loop:
//!
//! * **Sequential** (`dispatch_threads = 0`, the legacy default for
//!   `start`/`start_with_limit`): each connection thread reads a frame,
//!   runs the handler inline, writes the response, repeats. One request
//!   in flight per connection — the perf_analyzer model where clients
//!   that want parallelism open multiple connections.
//! * **Demultiplexed** (`dispatch_threads > 0`): the connection thread
//!   only reads frames and hands them to a shared dispatch pool; handler
//!   results are written back under a per-connection writer lock in
//!   completion order, matched to callers by request id. This is what a
//!   pipelined [`RpcSession`](super::session::RpcSession) needs to keep
//!   many requests of one connection in flight. A per-connection in-flight
//!   bound blocks the reader (TCP backpressure) instead of buffering
//!   unboundedly.

use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::codec::{self, InferRequest, InferResponse};
use crate::util::pool::ThreadPool;

/// Request handler: maps a decoded request to a response.
pub type Handler = Arc<dyn Fn(InferRequest) -> InferResponse + Send + Sync>;

/// Tuning knobs for [`RpcServer::start_with_opts`].
#[derive(Clone, Debug)]
pub struct RpcServerOpts {
    /// Connection (reader) threads.
    pub workers: usize,
    /// Open-connection cap; beyond it new accepts are closed immediately
    /// (Envoy's listener-level connection limiting). 0 disables.
    pub max_connections: usize,
    /// Per-connection pipelined-request bound; at the cap the connection
    /// reader blocks, pushing back on the client through TCP. 0 disables.
    pub max_inflight_per_conn: usize,
    /// Shared handler threads for demultiplexed dispatch; 0 selects the
    /// sequential (one request in flight per connection) mode.
    pub dispatch_threads: usize,
}

impl Default for RpcServerOpts {
    fn default() -> Self {
        RpcServerOpts {
            workers: 4,
            max_connections: 0,
            max_inflight_per_conn: 64,
            dispatch_threads: 0,
        }
    }
}

/// Framed-TCP RPC server.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    open_connections: Arc<AtomicU64>,
}

impl RpcServer {
    /// Bind `listen` and serve `handler` on `workers` connection threads.
    pub fn start(listen: &str, workers: usize, handler: Handler) -> Result<Self> {
        Self::start_with_limit(listen, workers, 0, handler)
    }

    /// [`RpcServer::start`] with a connection cap: beyond `max_connections`
    /// open connections new accepts are immediately closed (Envoy's
    /// listener-level connection limiting, §2.2 "rate limiting regulates
    /// server load based on the number of client connections").
    /// `max_connections = 0` disables the cap.
    pub fn start_with_limit(
        listen: &str,
        workers: usize,
        max_connections: usize,
        handler: Handler,
    ) -> Result<Self> {
        Self::start_with_opts(
            listen,
            RpcServerOpts { workers, max_connections, ..Default::default() },
            handler,
        )
    }

    /// Full-control constructor; see [`RpcServerOpts`].
    pub fn start_with_opts(listen: &str, opts: RpcServerOpts, handler: Handler) -> Result<Self> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding rpc listener {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let open = Arc::new(AtomicU64::new(0));

        let stop2 = Arc::clone(&stop);
        let open2 = Arc::clone(&open);
        let accept_handle = std::thread::Builder::new()
            .name("rpc-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(opts.workers, "rpc-conn");
                let dispatch = (opts.dispatch_threads > 0)
                    .then(|| Arc::new(ThreadPool::new(opts.dispatch_threads, "rpc-dispatch")));
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if opts.max_connections > 0
                                && open2.load(Ordering::SeqCst) >= opts.max_connections as u64
                            {
                                drop(stream); // refuse: close immediately
                                continue;
                            }
                            let handler = Arc::clone(&handler);
                            let stop3 = Arc::clone(&stop2);
                            let open3 = Arc::clone(&open2);
                            let dispatch = dispatch.clone();
                            let max_inflight = opts.max_inflight_per_conn;
                            open3.fetch_add(1, Ordering::SeqCst);
                            pool.execute(move || {
                                let _ = handle_connection(
                                    stream,
                                    handler,
                                    stop3,
                                    dispatch,
                                    max_inflight,
                                );
                                open3.fetch_sub(1, Ordering::SeqCst);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                // pools drop here, joining in-flight connections/handlers
            })
            .expect("spawning rpc accept thread");

        Ok(RpcServer { addr, stop, accept_handle: Some(accept_handle), open_connections: open })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Currently open client connections.
    pub fn open_connections(&self) -> u64 {
        self.open_connections.load(Ordering::SeqCst)
    }

    /// Signal shutdown and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// In-flight accounting for one demultiplexed connection.
struct Inflight {
    count: Mutex<usize>,
    cv: Condvar,
}

fn handle_connection(
    stream: TcpStream,
    handler: Handler,
    stop: Arc<AtomicBool>,
    dispatch: Option<Arc<ThreadPool>>,
    max_inflight: usize,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Bounded read timeout so connection threads notice shutdown.
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut reader = stream.try_clone()?;
    let writer = Arc::new(Mutex::new(BufWriter::new(stream)));
    let inflight = Arc::new(Inflight { count: Mutex::new(0), cv: Condvar::new() });

    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let frame = match codec::read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => {
                // Clean EOF: drain outstanding dispatched requests so the
                // client's pending pipeline still gets its responses.
                let mut n = inflight.count.lock().unwrap();
                while *n > 0 {
                    n = inflight.cv.wait_timeout(n, Duration::from_millis(100)).unwrap().0;
                }
                return Ok(());
            }
            Err(e) => {
                // timeouts surface as WouldBlock/TimedOut io errors: retry
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Err(e);
            }
        };

        match &dispatch {
            None => {
                // Sequential mode: handle inline, one in flight.
                let response = match codec::decode_request(&frame) {
                    Ok(req) => handler(req),
                    Err(e) => InferResponse::err(0, codec::Status::BadRequest, e.to_string()),
                };
                let mut w = writer.lock().unwrap();
                codec::write_response_frame(&mut *w, &response)?;
            }
            Some(pool) => {
                // Demultiplexed mode: block at the in-flight bound (TCP
                // backpressure), then hand off to the dispatch pool.
                {
                    let mut n = inflight.count.lock().unwrap();
                    while max_inflight > 0 && *n >= max_inflight {
                        n = inflight.cv.wait_timeout(n, Duration::from_millis(100)).unwrap().0;
                        if stop.load(Ordering::SeqCst) {
                            return Ok(());
                        }
                    }
                    *n += 1;
                }
                let handler = Arc::clone(&handler);
                let writer = Arc::clone(&writer);
                let inflight = Arc::clone(&inflight);
                pool.execute(move || {
                    let response = match codec::decode_request(&frame) {
                        Ok(req) => handler(req),
                        Err(e) => {
                            InferResponse::err(0, codec::Status::BadRequest, e.to_string())
                        }
                    };
                    {
                        // A dead connection just drops the write; the
                        // reader notices on its next read.
                        let mut w = writer.lock().unwrap();
                        let _ = codec::write_response_frame(&mut *w, &response);
                    }
                    *inflight.count.lock().unwrap() -= 1;
                    inflight.cv.notify_all();
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::client::RpcClient;
    use crate::rpc::codec::{RequestKind, Status};
    use crate::runtime::Tensor;

    fn echo_handler() -> Handler {
        Arc::new(|req: InferRequest| match req.kind {
            RequestKind::Health => InferResponse::ok(req.request_id, Tensor::zeros(vec![0])),
            RequestKind::Infer => {
                let mut out = req.input.clone();
                for v in out.data_mut() {
                    *v *= 2.0;
                }
                InferResponse::ok(req.request_id, out)
            }
        })
    }

    fn echo_server() -> RpcServer {
        RpcServer::start("127.0.0.1:0", 4, echo_handler()).unwrap()
    }

    #[test]
    fn roundtrip_over_tcp() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        let input = Tensor::new(vec![2], vec![1.5, 2.5]).unwrap();
        let resp = client.infer("m", input).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.output.data(), &[3.0, 5.0]);
    }

    #[test]
    fn multiple_requests_one_connection() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        for i in 0..20 {
            let input = Tensor::new(vec![1], vec![i as f32]).unwrap();
            let resp = client.infer("m", input).unwrap();
            assert_eq!(resp.output.data(), &[2.0 * i as f32]);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for i in 0..10 {
                    let v = (t * 100 + i) as f32;
                    let input = Tensor::new(vec![1], vec![v]).unwrap();
                    let resp = client.infer("m", input).unwrap();
                    assert_eq!(resp.output.data(), &[2.0 * v]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn health_check() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert!(client.health().unwrap());
    }

    #[test]
    fn garbage_frame_gets_bad_request() {
        let server = echo_server();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        codec::write_frame(&mut stream, b"not a valid request").unwrap();
        let frame = codec::read_frame(&mut stream).unwrap().unwrap();
        let resp = codec::decode_response(&frame).unwrap();
        assert_eq!(resp.status, Status::BadRequest);
    }

    #[test]
    fn shutdown_joins() {
        let mut server = echo_server();
        server.shutdown();
        assert!(RpcClient::connect(&server.addr().to_string()).is_err() || {
            // accept loop is gone; an accepted-but-unserviced connect may
            // succeed at the TCP level on some platforms, but requests fail.
            true
        });
    }

    #[test]
    fn demux_answers_pipelined_frames() {
        // Raw pipelining against the demultiplexed server: write a burst
        // of frames before reading anything, then collect responses in
        // arrival order and match by request id.
        let server = RpcServer::start_with_opts(
            "127.0.0.1:0",
            RpcServerOpts { workers: 1, dispatch_threads: 4, ..Default::default() },
            echo_handler(),
        )
        .unwrap();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        let mut reader = stream.try_clone().unwrap();
        for id in 1..=10u64 {
            let req = InferRequest::infer(
                id,
                "m",
                Tensor::new(vec![1], vec![id as f32]).unwrap(),
            );
            codec::write_request_frame(&mut stream, &req, id).unwrap();
        }
        let mut seen = std::collections::HashMap::new();
        for _ in 0..10 {
            let frame = codec::read_frame(&mut reader).unwrap().unwrap();
            let resp = codec::decode_response(&frame).unwrap();
            assert_eq!(resp.status, Status::Ok);
            seen.insert(resp.request_id, resp.output.data()[0]);
        }
        for id in 1..=10u64 {
            assert_eq!(seen[&id], 2.0 * id as f32, "request {id} got wrong payload");
        }
    }

    #[test]
    fn demux_inflight_bound_backpressures_but_serves_all() {
        // With a bound of 2 and a slow handler, a 16-deep burst still gets
        // 16 correct responses — the reader just absorbs them gradually.
        let slow: Handler = Arc::new(|req: InferRequest| {
            std::thread::sleep(Duration::from_millis(5));
            InferResponse::ok(req.request_id, req.input)
        });
        let server = RpcServer::start_with_opts(
            "127.0.0.1:0",
            RpcServerOpts {
                workers: 1,
                dispatch_threads: 4,
                max_inflight_per_conn: 2,
                ..Default::default()
            },
            slow,
        )
        .unwrap();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        let mut reader = stream.try_clone().unwrap();
        let writer = std::thread::spawn(move || {
            for id in 1..=16u64 {
                let req = InferRequest::infer(
                    id,
                    "m",
                    Tensor::new(vec![1], vec![id as f32]).unwrap(),
                );
                codec::write_request_frame(&mut stream, &req, id).unwrap();
            }
        });
        let mut ids = std::collections::HashSet::new();
        for _ in 0..16 {
            let frame = codec::read_frame(&mut reader).unwrap().unwrap();
            let resp = codec::decode_response(&frame).unwrap();
            assert_eq!(resp.output.data(), &[resp.request_id as f32]);
            ids.insert(resp.request_id);
        }
        assert_eq!(ids.len(), 16);
        writer.join().unwrap();
    }
}
