//! Trigger-metric queries: the KEDA `ScaledObject` trigger analogue.
//!
//! The paper's default trigger is "the average request queue latency
//! across Triton servers"; the config's `autoscaler.metric` string picks
//! one of a small query language over the [`MetricStore`]:
//!
//! * `queue_latency_avg` — per-request mean queue wait over a trailing
//!   window, computed Triton/KEDA-style as Δ(queue_seconds_sum) /
//!   Δ(request_count) aggregated across instances (the default; robust
//!   to idle decay and sampling phase). An optional window suffix picks
//!   the trailing window in clock seconds: `queue_latency_avg:60`.
//! * `queue_latency_ewma` — mean of every instance's smoothed
//!   `queue_latency_seconds` gauge (the executor's EWMA signal);
//! * `queue_latency_max` — worst instance's gauge instead of the mean;
//! * `queue_depth_avg` — mean queued requests per instance;
//! * `gpu_utilization_avg` — mean busy fraction;
//! * `series:<id>` — the latest value of an arbitrary stored series
//!   ("an arbitrary external metric", §2.2/§2.4).

use std::time::Duration;

use crate::metrics::MetricStore;
use crate::util::clock::Clock;

/// Default trailing window for windowed (rate-of-sums) queries.
const DEFAULT_WINDOW_SECS: f64 = 30.0;

/// A compiled trigger query.
pub struct MetricQuery {
    kind: QueryKind,
    store: MetricStore,
    clock: Clock,
}

enum QueryKind {
    /// Δsum/Δcount of a histogram series family over a trailing window.
    WindowedPerRequest { base: &'static str, window: Duration },
    AvgPrefix(&'static str),
    MaxPrefix(&'static str),
    Series(String),
}

impl MetricQuery {
    /// Parse an `autoscaler.metric` config string. Unknown names fall back
    /// to the paper's default (avg queue latency) with a warning, so a
    /// typo degrades to default behaviour rather than a dead autoscaler.
    pub fn parse(spec: &str, store: MetricStore, clock: Clock) -> Self {
        let (name, window) = match spec.split_once(':') {
            Some((n, w)) if n == "queue_latency_avg" => {
                let secs = w.parse().unwrap_or(DEFAULT_WINDOW_SECS);
                (n, Duration::from_secs_f64(secs))
            }
            _ => (spec, Duration::from_secs_f64(DEFAULT_WINDOW_SECS)),
        };
        let kind = match name {
            "queue_latency_avg" => QueryKind::WindowedPerRequest {
                base: "request_queue_seconds",
                window,
            },
            "queue_latency_ewma" => QueryKind::AvgPrefix("queue_latency_seconds"),
            "queue_latency_max" => QueryKind::MaxPrefix("queue_latency_seconds"),
            "queue_depth_avg" => QueryKind::AvgPrefix("queue_depth"),
            "gpu_utilization_avg" => QueryKind::AvgPrefix("gpu_utilization"),
            other => {
                if let Some(series) = other.strip_prefix("series:") {
                    QueryKind::Series(series.to_string())
                } else {
                    log::warn!(
                        "unknown autoscaler metric '{other}', using queue_latency_avg"
                    );
                    QueryKind::WindowedPerRequest {
                        base: "request_queue_seconds",
                        window,
                    }
                }
            }
        };
        MetricQuery { kind, store, clock }
    }

    /// Evaluate the query. `None` until the store has data.
    pub fn sample(&self) -> Option<f64> {
        match &self.kind {
            QueryKind::WindowedPerRequest { base, window } => {
                self.windowed_per_request(base, *window)
            }
            QueryKind::AvgPrefix(prefix) => self.store.avg_latest_prefix(prefix),
            QueryKind::MaxPrefix(prefix) => {
                let ids = self.store.series_ids();
                let vals: Vec<f64> = ids
                    .iter()
                    .filter(|id| id.starts_with(prefix))
                    .filter_map(|id| self.store.latest(id).map(|(_, v)| v))
                    .collect();
                if vals.is_empty() {
                    None
                } else {
                    Some(vals.into_iter().fold(f64::NEG_INFINITY, f64::max))
                }
            }
            QueryKind::Series(id) => self.store.latest(id).map(|(_, v)| v),
        }
    }

    /// Triton/KEDA-style trigger: total Δ(sum of queue seconds) divided by
    /// total Δ(request count) across instances over the trailing window —
    /// the per-request average queue wait, weighted by traffic. Instances
    /// scraped but idle contribute 0/0; a deployment with *no* completed
    /// requests in the window reads 0 (idle ⇒ scale-down pressure).
    fn windowed_per_request(&self, base: &str, window: Duration) -> Option<f64> {
        let now = self.clock.now_secs();
        let t0 = now - window.as_secs_f64();
        let prefix = format!("{base}{{");
        let mut dsum = 0.0f64;
        let mut dcount = 0.0f64;
        let mut any_series = false;
        for id in self.store.series_ids() {
            if !(id.starts_with(&prefix) && id.ends_with(":sum")) {
                continue;
            }
            let count_id = format!("{}:count", &id[..id.len() - ":sum".len()]);
            let spts = self.store.range(&id, t0, now);
            let cpts = self.store.range(&count_id, t0, now);
            if spts.len() < 2 || cpts.len() < 2 {
                continue;
            }
            any_series = true;
            dsum += spts.last().unwrap().1 - spts[0].1;
            dcount += cpts.last().unwrap().1 - cpts[0].1;
        }
        if !any_series {
            return None; // no data yet — hold
        }
        if dcount <= 0.0 {
            return Some(0.0); // nothing served: no queueing pressure
        }
        Some((dsum / dcount).max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn store() -> MetricStore {
        let s = MetricStore::new(Duration::from_secs(600));
        s.push("queue_latency_seconds{instance=\"a\"}", 1.0, 0.2);
        s.push("queue_latency_seconds{instance=\"b\"}", 1.0, 0.4);
        s.push("queue_depth{instance=\"a\"}", 1.0, 3.0);
        s.push("gpu_utilization{instance=\"a\"}", 1.0, 0.9);
        s.push("custom_series", 1.0, 42.0);
        s
    }

    /// Clock pinned at t=10s so windowed queries see the pushed points.
    fn clock_at_10s() -> Clock {
        let c = Clock::simulated();
        c.advance(Duration::from_secs(10));
        c
    }

    #[test]
    fn ewma_queue_latency() {
        let q = MetricQuery::parse("queue_latency_ewma", store(), Clock::real());
        assert!((q.sample().unwrap() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn windowed_queue_latency_per_request() {
        let s = MetricStore::new(Duration::from_secs(600));
        // instance a: 10 requests, 1.0s of queue time in the window
        s.push("request_queue_seconds{instance=\"a\"}:sum", 1.0, 5.0);
        s.push("request_queue_seconds{instance=\"a\"}:sum", 9.0, 6.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 1.0, 100.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 9.0, 110.0);
        // instance b: 30 requests, 0.5s of queue time
        s.push("request_queue_seconds{instance=\"b\"}:sum", 1.0, 0.0);
        s.push("request_queue_seconds{instance=\"b\"}:sum", 9.0, 0.5);
        s.push("request_queue_seconds{instance=\"b\"}:count", 1.0, 0.0);
        s.push("request_queue_seconds{instance=\"b\"}:count", 9.0, 30.0);
        let q = MetricQuery::parse("queue_latency_avg", s, clock_at_10s());
        // (1.0 + 0.5) / (10 + 30) = 0.0375
        assert!((q.sample().unwrap() - 0.0375).abs() < 1e-9);
    }

    #[test]
    fn windowed_no_data_is_none_idle_is_zero() {
        let s = MetricStore::new(Duration::from_secs(600));
        let q = MetricQuery::parse("queue_latency_avg", s.clone(), clock_at_10s());
        assert_eq!(q.sample(), None);
        // series exist but no new requests in the window
        s.push("request_queue_seconds{instance=\"a\"}:sum", 1.0, 5.0);
        s.push("request_queue_seconds{instance=\"a\"}:sum", 9.0, 5.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 1.0, 50.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 9.0, 50.0);
        assert_eq!(q.sample(), Some(0.0));
    }

    #[test]
    fn windowed_respects_window_suffix() {
        let s = MetricStore::new(Duration::from_secs(600));
        // old spike outside a 5s window ending at t=10
        s.push("request_queue_seconds{instance=\"a\"}:sum", 1.0, 0.0);
        s.push("request_queue_seconds{instance=\"a\"}:sum", 2.0, 100.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 1.0, 0.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 2.0, 10.0);
        // quiet recent window
        s.push("request_queue_seconds{instance=\"a\"}:sum", 6.0, 100.0);
        s.push("request_queue_seconds{instance=\"a\"}:sum", 9.0, 100.1);
        s.push("request_queue_seconds{instance=\"a\"}:count", 6.0, 10.0);
        s.push("request_queue_seconds{instance=\"a\"}:count", 9.0, 20.0);
        let narrow = MetricQuery::parse("queue_latency_avg:5", s.clone(), clock_at_10s());
        assert!((narrow.sample().unwrap() - 0.01).abs() < 1e-9);
        let wide = MetricQuery::parse("queue_latency_avg:20", s, clock_at_10s());
        assert!(wide.sample().unwrap() > 1.0);
    }

    #[test]
    fn max_queue_latency() {
        let q = MetricQuery::parse("queue_latency_max", store(), Clock::real());
        assert!((q.sample().unwrap() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn queue_depth_and_util() {
        let s = store();
        let q = MetricQuery::parse("queue_depth_avg", s.clone(), Clock::real());
        assert!((q.sample().unwrap() - 3.0).abs() < 1e-9);
        let q = MetricQuery::parse("gpu_utilization_avg", s, Clock::real());
        assert!((q.sample().unwrap() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn arbitrary_series() {
        let q = MetricQuery::parse("series:custom_series", store(), Clock::real());
        assert_eq!(q.sample(), Some(42.0));
    }

    #[test]
    fn unknown_falls_back_to_default() {
        // Falls back to the windowed default; empty store → None.
        let q = MetricQuery::parse("qeue_latency_avg", store(), clock_at_10s());
        assert_eq!(q.sample(), None);
    }

    #[test]
    fn empty_store_is_none() {
        let s = MetricStore::new(Duration::from_secs(10));
        let q = MetricQuery::parse("queue_latency_avg", s, Clock::real());
        assert_eq!(q.sample(), None);
    }
}
