//! Load-based autoscaling — the KEDA analogue (§2.4).
//!
//! "KEDA is configured to launch additional Triton instances when a
//! user-defined metric exceeds a given threshold and, conversely, to shut
//! down servers when the metric value falls below the threshold. The
//! default scaling metric is defined as the average request queue latency
//! across Triton servers."
//!
//! Split into two layers:
//!
//! * [`ScalerCore`] — the pure decision function. Given (time, metric,
//!   current desired) it applies threshold / cooldown / stabilization /
//!   step / bounds rules and returns the new desired replica count. Being
//!   pure, it is exhaustively unit- and property-tested without threads.
//! * [`Autoscaler`] — the poll loop: samples the configured metric from
//!   the [`MetricStore`], feeds the core, and pushes decisions into the
//!   cluster's `desired_replicas` — exactly KEDA's relationship to a
//!   Deployment.
//!
//! On top of that sits **per-model autoscaling** (`autoscaler.per_model`),
//! the modelmesh follow-on: instead of one global target moved by a
//! cluster-wide metric, [`PerModelScaler`] runs one [`ScalerCore`] per
//! served model, fed by the placement controller's per-model demand
//! signal (routed-request rate plus live queue depth, per replica). A hot
//! model gains pods that boot advertising only that model (its boot
//! profile), while `autoscaler.max_replicas` remains the *total* pod
//! budget shared by every model — the planner hands budget to the models
//! with the highest per-replica load first. [`PerModelPlanner`] is the
//! pure layer (exhaustively testable without threads), [`PerModelScaler`]
//! the poll loop.

pub mod metric;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::AutoscalerConfig;
use crate::metrics::registry::{labels, Counter, Gauge, Registry};
use crate::metrics::MetricStore;
use crate::orchestrator::Cluster;
use crate::telemetry::flight::{DecisionEvent, LoopTicker, RecorderHandle};
use crate::util::clock::Clock;

pub use metric::MetricQuery;

/// Demand probe for per-model scaling: `(model, now_secs) -> demand`
/// (routed req/s + queued requests). The deployment wires this to
/// [`PlacementController::demand_for`](crate::modelmesh::PlacementController::demand_for),
/// so scaling and placement react to the same signal.
pub type DemandProbe = Arc<dyn Fn(&str, f64) -> f64 + Send + Sync>;

/// CPU-share probe for the CPU-group scaler: `model -> fraction of the
/// model's warm replicas that are CPU pods` (0.0 when the model has no
/// warm replicas). The deployment wires this to the mesh router's pool
/// view, classifying an endpoint as CPU when its backend set lacks the
/// GPU runtime.
pub type CpuShareProbe = Arc<dyn Fn(&str) -> f64 + Send + Sync>;

/// A scaling decision from one evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Keep the current replica count.
    Hold,
    /// Scale up to the contained count.
    Up(usize),
    /// Scale down to the contained count.
    Down(usize),
}

impl Decision {
    /// The target replica count, if the decision changes it.
    pub fn target(&self) -> Option<usize> {
        match self {
            Decision::Hold => None,
            Decision::Up(n) | Decision::Down(n) => Some(*n),
        }
    }
}

/// Pure threshold/cooldown/stabilization state machine.
///
/// Scale-up: metric > `threshold`, rate-limited by `scale_up_cooldown`.
/// Scale-down: metric must stay below `threshold * scale_down_ratio` for a
/// full `scale_down_stabilization` window (KEDA's stabilization semantics:
/// any excursion above the low-water mark resets the window).
pub struct ScalerCore {
    cfg: AutoscalerConfig,
    /// Clock-seconds of the last scale-up.
    last_scale_up: f64,
    /// Start of the current below-low-water streak (None = streak broken).
    low_since: Option<f64>,
}

impl ScalerCore {
    /// Fresh core; `now` is the current clock time in seconds.
    pub fn new(cfg: AutoscalerConfig, now: f64) -> Self {
        ScalerCore {
            cfg,
            // Allow an immediate first scale-up.
            last_scale_up: now - 1e9,
            low_since: None,
        }
    }

    /// The configured bounds, clamped.
    fn clamp(&self, n: usize) -> usize {
        n.clamp(self.cfg.min_replicas, self.cfg.max_replicas)
    }

    /// Low-water mark below which scale-down stabilization accumulates.
    pub fn low_water(&self) -> f64 {
        self.cfg.threshold * self.cfg.scale_down_ratio
    }

    /// Evaluate one sample. `current` is the cluster's desired replicas.
    pub fn evaluate(&mut self, now: f64, metric: f64, current: usize) -> Decision {
        // Track the below-low-water streak regardless of what we decide.
        if metric < self.low_water() {
            if self.low_since.is_none() {
                self.low_since = Some(now);
            }
        } else {
            self.low_since = None;
        }

        if metric > self.cfg.threshold {
            if current >= self.cfg.max_replicas {
                return Decision::Hold;
            }
            if now - self.last_scale_up < self.cfg.scale_up_cooldown.as_secs_f64() {
                return Decision::Hold;
            }
            self.last_scale_up = now;
            return Decision::Up(self.clamp(current + self.cfg.step));
        }

        if let Some(since) = self.low_since {
            if current > self.cfg.min_replicas
                && now - since >= self.cfg.scale_down_stabilization.as_secs_f64()
            {
                // Restart the window so consecutive downs are spaced by a
                // full stabilization period each.
                self.low_since = Some(now);
                return Decision::Down(self.clamp(current.saturating_sub(self.cfg.step)));
            }
        }
        Decision::Hold
    }
}

/// The running autoscaler: poll loop + metrics.
pub struct Autoscaler {
    core: Arc<Mutex<ScalerCore>>,
    query: Arc<MetricQuery>,
    cluster: Arc<Cluster>,
    cfg: AutoscalerConfig,
    clock: Clock,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    m_metric: crate::metrics::registry::Gauge,
    m_scale_ups: crate::metrics::registry::Counter,
    m_scale_downs: crate::metrics::registry::Counter,
    recorder: RecorderHandle,
    ticker: LoopTicker,
}

impl Autoscaler {
    /// Start polling `store` every `cfg.poll_interval` of clock time.
    pub fn start(
        cfg: AutoscalerConfig,
        cluster: Arc<Cluster>,
        store: MetricStore,
        clock: Clock,
        registry: Registry,
    ) -> Arc<Self> {
        let query = Arc::new(MetricQuery::parse(&cfg.metric, store, clock.clone()));
        let l = labels(&[]);
        let scaler = Arc::new(Autoscaler {
            core: Arc::new(Mutex::new(ScalerCore::new(cfg.clone(), clock.now_secs()))),
            query,
            cluster,
            cfg: cfg.clone(),
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            m_metric: registry.gauge("autoscaler_metric", &l),
            m_scale_ups: registry.counter("autoscaler_scale_ups_total", &l),
            m_scale_downs: registry.counter("autoscaler_scale_downs_total", &l),
            recorder: RecorderHandle::default(),
            ticker: LoopTicker::new(&registry, clock, "autoscaler"),
        });
        if cfg.enabled {
            let s = Arc::clone(&scaler);
            let handle = std::thread::Builder::new()
                .name("autoscaler".into())
                .spawn(move || {
                    while !s.stop.load(Ordering::SeqCst) {
                        s.ticker.tick(|| s.evaluate_once());
                        s.clock.sleep(s.cfg.poll_interval);
                    }
                })
                .expect("spawning autoscaler");
            *scaler.handle.lock().unwrap() = Some(handle);
        }
        scaler
    }

    /// The flight-recorder slot scaling decisions land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// One synchronous evaluation (used by the poll loop and by
    /// simulated-time tests). Returns the decision taken.
    pub fn evaluate_once(&self) -> Decision {
        let now = self.clock.now_secs();
        let Some(metric) = self.query.sample() else {
            return Decision::Hold; // no data yet
        };
        self.m_metric.set(metric);
        let current = self.cluster.desired();
        let decision = self.core.lock().unwrap().evaluate(now, metric, current);
        match decision {
            Decision::Up(n) => {
                log::info!(
                    "autoscaler: metric {metric:.4} > {:.4}, scaling {current} -> {n}",
                    self.cfg.threshold
                );
                self.m_scale_ups.inc();
                self.cluster.set_desired(n);
            }
            Decision::Down(n) => {
                log::info!("autoscaler: metric {metric:.4} low, scaling {current} -> {n}");
                self.m_scale_downs.inc();
                self.cluster.set_desired(n);
            }
            Decision::Hold => {}
        }
        if let Some(n) = decision.target() {
            self.recorder.record(
                DecisionEvent::new("autoscaler", "scale_target")
                    .input("metric", metric)
                    .input("threshold", self.cfg.threshold)
                    .input("from", current as f64)
                    .input("to", n as f64)
                    .action(format!("global desired {current} -> {n}")),
            );
        }
        decision
    }

    /// Latest sampled metric value.
    pub fn metric_value(&self) -> f64 {
        self.m_metric.get()
    }

    /// Stop the poll loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Pure per-model planning layer: one [`ScalerCore`] per model plus the
/// shared total-pod budget (`autoscaler.max_replicas`).
///
/// Each core runs with the parent section's cooldown / stabilization /
/// step / ratio knobs and the `per_model` threshold and bounds. The
/// metric each core sees is the model's *per-replica* demand
/// (`demand / max(current, 1)`), so the threshold has the same meaning
/// as the placement controller's load threshold.
pub struct PerModelPlanner {
    cores: BTreeMap<String, ScalerCore>,
    budget: usize,
}

impl PerModelPlanner {
    /// Planner over `models`; `now` is the current clock time in seconds.
    pub fn new(cfg: &AutoscalerConfig, models: &[String], now: f64) -> Self {
        let cores = models
            .iter()
            .map(|m| {
                let mut core_cfg = cfg.clone();
                core_cfg.threshold = cfg.per_model.threshold;
                core_cfg.min_replicas = cfg.per_model.min_replicas;
                core_cfg.max_replicas = cfg.per_model.max_replicas;
                (m.clone(), ScalerCore::new(core_cfg, now))
            })
            .collect();
        PerModelPlanner { cores, budget: cfg.max_replicas }
    }

    /// Replace the shared total-pod budget. In federated mode the global
    /// rebalancer shifts budget between the site-local planners through
    /// this — a site absorbing spillover is granted pods that a quiet
    /// site gives up, while each site's planner still decides *which
    /// models* spend them.
    pub fn set_budget(&mut self, budget: usize) {
        self.budget = budget;
    }

    /// The current shared total-pod budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// One evaluation over all models: total `demand` and `current` pod
    /// targets in, `(model, new target)` changes out. Models are visited
    /// hottest (highest per-replica demand) first, so the shared budget
    /// goes where the pressure is. A scale-up that would push the fleet
    /// past the budget is dropped — its cooldown still stamps, so a
    /// budget-starved model retries on the cooldown cadence rather than
    /// every poll.
    pub fn plan(
        &mut self,
        now: f64,
        demand: &BTreeMap<String, f64>,
        current: &BTreeMap<String, usize>,
    ) -> Vec<(String, usize)> {
        let mut total: usize = current.values().sum();
        let mut order: Vec<(String, f64)> = self
            .cores
            .keys()
            .map(|m| {
                let cur = current.get(m).copied().unwrap_or(0).max(1);
                let d = demand.get(m).copied().unwrap_or(0.0);
                (m.clone(), d / cur as f64)
            })
            .collect();
        order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));

        let mut changes = Vec::new();
        for (model, per_replica) in order {
            let cur = current.get(&model).copied().unwrap_or(0);
            let core = self.cores.get_mut(&model).expect("core per model");
            match core.evaluate(now, per_replica, cur) {
                Decision::Up(n) => {
                    let grow = n.saturating_sub(cur);
                    if total + grow <= self.budget {
                        total += grow;
                        changes.push((model, n));
                    }
                }
                Decision::Down(n) => {
                    total = total.saturating_sub(cur.saturating_sub(n));
                    changes.push((model, n));
                }
                Decision::Hold => {}
            }
        }
        changes
    }
}

struct ModelScaleHandles {
    demand: Gauge,
    desired: Gauge,
    ups: Counter,
    downs: Counter,
}

/// The running per-model autoscaler: polls the demand probe on the
/// configured interval and pushes per-model targets into the cluster
/// (which must be in per-model mode, [`Cluster::start_per_model`]).
pub struct PerModelScaler {
    planner: Mutex<PerModelPlanner>,
    demand: DemandProbe,
    cluster: Arc<Cluster>,
    models: Vec<String>,
    cfg: AutoscalerConfig,
    clock: Clock,
    stop: Arc<AtomicBool>,
    /// Paused scalers hold all targets (federation: a failed site's
    /// scaler must not fight the outage drain).
    paused: AtomicBool,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    per_model: BTreeMap<String, ModelScaleHandles>,
    /// Site label on decision events in federated mode.
    site: Option<String>,
    recorder: RecorderHandle,
    ticker: LoopTicker,
}

impl PerModelScaler {
    /// Start polling every `cfg.poll_interval` of clock time.
    pub fn start(
        cfg: AutoscalerConfig,
        models: Vec<String>,
        cluster: Arc<Cluster>,
        demand: DemandProbe,
        clock: Clock,
        registry: Registry,
    ) -> Arc<Self> {
        Self::start_inner(cfg, models, cluster, demand, clock, registry, None)
    }

    /// [`PerModelScaler::start`] as one federation site's local scaler:
    /// the `autoscaler_model_*` series gain a `site` label and the
    /// planner's budget becomes the site's slice of the global pod
    /// budget, adjusted at runtime by the rebalancer via
    /// [`PerModelScaler::set_budget`].
    pub fn start_for_site(
        cfg: AutoscalerConfig,
        models: Vec<String>,
        cluster: Arc<Cluster>,
        demand: DemandProbe,
        clock: Clock,
        registry: Registry,
        site: &str,
    ) -> Arc<Self> {
        Self::start_inner(cfg, models, cluster, demand, clock, registry, Some(site))
    }

    fn start_inner(
        cfg: AutoscalerConfig,
        models: Vec<String>,
        cluster: Arc<Cluster>,
        demand: DemandProbe,
        clock: Clock,
        registry: Registry,
        site: Option<&str>,
    ) -> Arc<Self> {
        let per_model = models
            .iter()
            .map(|m| {
                let l = match site {
                    None => labels(&[("model", m)]),
                    Some(site) => labels(&[("model", m), ("site", site)]),
                };
                (
                    m.clone(),
                    ModelScaleHandles {
                        demand: registry.gauge("autoscaler_model_demand", &l),
                        desired: registry.gauge("autoscaler_model_desired", &l),
                        ups: registry.counter("autoscaler_model_scale_ups_total", &l),
                        downs: registry.counter("autoscaler_model_scale_downs_total", &l),
                    },
                )
            })
            .collect();
        let scaler = Arc::new(PerModelScaler {
            planner: Mutex::new(PerModelPlanner::new(&cfg, &models, clock.now_secs())),
            demand,
            cluster,
            models,
            cfg: cfg.clone(),
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            paused: AtomicBool::new(false),
            handle: Mutex::new(None),
            per_model,
            site: site.map(str::to_string),
            recorder: RecorderHandle::default(),
            ticker: LoopTicker::new(&registry, clock, "per_model_scaler"),
        });
        let s = Arc::clone(&scaler);
        let handle = std::thread::Builder::new()
            .name("per-model-autoscaler".into())
            .spawn(move || {
                while !s.stop.load(Ordering::SeqCst) {
                    s.ticker.tick(|| s.evaluate_once());
                    s.clock.sleep(s.cfg.poll_interval);
                }
            })
            .expect("spawning per-model autoscaler");
        *scaler.handle.lock().unwrap() = Some(handle);
        scaler
    }

    /// The flight-recorder slot scaling decisions land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// Replace the planner's shared pod budget (see
    /// [`PerModelPlanner::set_budget`]). Takes effect on the next
    /// evaluation; an over-budget fleet shrinks through the normal
    /// scale-down path rather than being culled immediately.
    pub fn set_budget(&self, budget: usize) {
        self.planner.lock().unwrap().set_budget(budget);
    }

    /// Suspend target changes (outage drain). The poll loop keeps
    /// running but every evaluation holds.
    pub fn pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    /// Resume target changes after [`PerModelScaler::pause`].
    pub fn resume(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    /// One synchronous evaluation (used by the poll loop and by tests).
    /// Returns the number of target changes applied.
    pub fn evaluate_once(&self) -> usize {
        if self.paused.load(Ordering::SeqCst) {
            return 0;
        }
        let now = self.clock.now_secs();
        let mut demand = BTreeMap::new();
        let mut current = BTreeMap::new();
        for m in &self.models {
            let d = (self.demand)(m, now);
            self.per_model[m].demand.set(d);
            demand.insert(m.clone(), d);
            current.insert(m.clone(), self.cluster.desired_for(m));
        }
        let (changes, budget) = {
            let mut planner = self.planner.lock().unwrap();
            let changes = planner.plan(now, &demand, &current);
            (changes, planner.budget())
        };
        for (model, n) in &changes {
            let cur = current[model];
            let h = &self.per_model[model];
            if *n > cur {
                h.ups.inc();
            } else {
                h.downs.inc();
            }
            log::info!(
                "per-model autoscaler: '{model}' demand {:.1}, pods {cur} -> {n}",
                demand[model]
            );
            self.cluster.set_desired_for(model, *n);
            h.desired.set(*n as f64);
            let mut ev = DecisionEvent::new("per_model_scaler", "scale_target")
                .model(model)
                .input("demand", demand[model])
                .input("from", cur as f64)
                .input("to", *n as f64)
                .input("budget", budget as f64)
                .action(format!("'{model}' pods {cur} -> {n}"));
            if let Some(site) = &self.site {
                ev = ev.site(site);
            }
            self.recorder.record(ev);
        }
        changes.len()
    }

    /// Stop the poll loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// CPU-group autoscaler (mixed-fleet follow-on): drives
/// [`Cluster::set_cpu_desired`] from the *class-partitioned* demand
/// signal. The trigger metric is the CPU pods' share of each
/// CPU-servable model's demand — `Σ demand(m) × cpu_share(m)` divided by
/// the current CPU pod count — so GPU backlog no longer inflates (or
/// masks) the CPU group's trigger, which was the failure mode behind the
/// earlier mixed-fleet validation warning. Bounds come from
/// `engines.cpu_replicas` (floor) and `engines.cpu_max_replicas` (cap);
/// the threshold is shared with per-model scaling
/// (`autoscaler.per_model.threshold`), both being per-replica demand.
pub struct CpuScaler {
    core: Mutex<ScalerCore>,
    demand: DemandProbe,
    cpu_share: CpuShareProbe,
    cluster: Arc<Cluster>,
    /// CPU-servable models (compat includes a CPU backend).
    models: Vec<String>,
    cfg: AutoscalerConfig,
    clock: Clock,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    m_demand: Gauge,
    m_desired: Gauge,
    recorder: RecorderHandle,
    ticker: LoopTicker,
}

impl CpuScaler {
    /// Start polling every `cfg.poll_interval` of clock time. `cpu_min`
    /// / `cpu_max` are the CPU group's bounds (`engines.cpu_replicas` /
    /// `engines.effective_cpu_max()`); the remaining knobs (cooldown,
    /// stabilization, step, per-model threshold) come from `cfg`.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        cfg: &AutoscalerConfig,
        cpu_min: usize,
        cpu_max: usize,
        models: Vec<String>,
        cluster: Arc<Cluster>,
        demand: DemandProbe,
        cpu_share: CpuShareProbe,
        clock: Clock,
        registry: Registry,
    ) -> Arc<Self> {
        let mut core_cfg = cfg.clone();
        core_cfg.threshold = cfg.per_model.threshold;
        core_cfg.min_replicas = cpu_min;
        core_cfg.max_replicas = cpu_max;
        let l = labels(&[]);
        let scaler = Arc::new(CpuScaler {
            core: Mutex::new(ScalerCore::new(core_cfg.clone(), clock.now_secs())),
            demand,
            cpu_share,
            cluster,
            models,
            cfg: core_cfg,
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            m_demand: registry.gauge("autoscaler_cpu_demand", &l),
            m_desired: registry.gauge("autoscaler_cpu_desired", &l),
            recorder: RecorderHandle::default(),
            ticker: LoopTicker::new(&registry, clock, "cpu_scaler"),
        });
        let s = Arc::clone(&scaler);
        let handle = std::thread::Builder::new()
            .name("cpu-autoscaler".into())
            .spawn(move || {
                while !s.stop.load(Ordering::SeqCst) {
                    s.ticker.tick(|| s.evaluate_once());
                    s.clock.sleep(s.cfg.poll_interval);
                }
            })
            .expect("spawning cpu autoscaler");
        *scaler.handle.lock().unwrap() = Some(handle);
        scaler
    }

    /// The flight-recorder slot scaling decisions land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// One synchronous evaluation (used by the poll loop and by tests).
    pub fn evaluate_once(&self) -> Decision {
        let now = self.clock.now_secs();
        let total: f64 = self
            .models
            .iter()
            .map(|m| (self.demand)(m, now) * (self.cpu_share)(m))
            .sum();
        self.m_demand.set(total);
        let current = self.cluster.cpu_desired();
        let per_replica = total / current.max(1) as f64;
        let decision = self.core.lock().unwrap().evaluate(now, per_replica, current);
        if let Some(n) = decision.target() {
            log::info!("cpu autoscaler: cpu demand {total:.1}, cpu pods {current} -> {n}");
            self.cluster.set_cpu_desired(n);
            self.recorder.record(
                DecisionEvent::new("cpu_scaler", "cpu_target")
                    .input("cpu_demand", total)
                    .input("per_replica", per_replica)
                    .input("from", current as f64)
                    .input("to", n as f64)
                    .action(format!("cpu pods {current} -> {n}")),
            );
        }
        self.m_desired.set(self.cluster.cpu_desired() as f64);
        decision
    }

    /// Stop the poll loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            enabled: true,
            metric: "queue_latency_avg".into(),
            threshold: 0.1,
            scale_down_ratio: 0.3, // low water 0.03
            min_replicas: 1,
            max_replicas: 10,
            poll_interval: Duration::from_secs(1),
            scale_up_cooldown: Duration::from_secs(5),
            scale_down_stabilization: Duration::from_secs(30),
            step: 1,
            per_model: Default::default(),
        }
    }

    /// Per-model planner config: budget 6 pods total, threshold 100
    /// per-replica demand, per-model bounds [1, 4].
    fn pm_cfg() -> AutoscalerConfig {
        let mut c = cfg();
        c.max_replicas = 6;
        c.per_model = crate::config::PerModelScalingConfig {
            enabled: true,
            threshold: 100.0,
            min_replicas: 1,
            max_replicas: 4,
        };
        c
    }

    fn map_f64(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn map_usize(pairs: &[(&str, usize)]) -> BTreeMap<String, usize> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn models() -> Vec<String> {
        vec!["hot".to_string(), "cold".to_string()]
    }

    #[test]
    fn scales_up_over_threshold() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(2));
    }

    #[test]
    fn cooldown_blocks_consecutive_ups() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(2));
        assert_eq!(core.evaluate(1.0, 0.5, 2), Decision::Hold);
        assert_eq!(core.evaluate(4.9, 0.5, 2), Decision::Hold);
        assert_eq!(core.evaluate(5.0, 0.5, 2), Decision::Up(3));
    }

    #[test]
    fn max_replicas_caps_up() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 10), Decision::Hold);
    }

    #[test]
    fn step_respected() {
        let mut c = cfg();
        c.step = 3;
        let mut core = ScalerCore::new(c, 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(4));
        assert_eq!(core.evaluate(100.0, 0.5, 9), Decision::Up(10)); // clamped
    }

    #[test]
    fn scale_down_needs_full_stabilization() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        // below low water from t=0
        assert_eq!(core.evaluate(0.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(15.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(29.9, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(30.0, 0.01, 4), Decision::Down(3));
        // window restarts: next down only after another 30s
        assert_eq!(core.evaluate(31.0, 0.01, 3), Decision::Hold);
        assert_eq!(core.evaluate(60.0, 0.01, 3), Decision::Down(2));
    }

    #[test]
    fn excursion_resets_stabilization() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.01, 4), Decision::Hold);
        // metric pops above low water mid-window
        assert_eq!(core.evaluate(20.0, 0.05, 4), Decision::Hold);
        assert_eq!(core.evaluate(30.0, 0.01, 4), Decision::Hold); // streak restarted at 30
        assert_eq!(core.evaluate(59.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(60.0, 0.01, 4), Decision::Down(3));
    }

    #[test]
    fn never_below_min() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.0, 1), Decision::Hold);
        assert_eq!(core.evaluate(1000.0, 0.0, 1), Decision::Hold);
    }

    #[test]
    fn mid_band_holds() {
        // between low water (0.03) and threshold (0.1): no action, ever.
        let mut core = ScalerCore::new(cfg(), 0.0);
        for t in 0..200 {
            assert_eq!(core.evaluate(t as f64, 0.05, 4), Decision::Hold);
        }
    }

    #[test]
    fn property_bounds_always_respected() {
        use crate::util::quick::{check, Gen};
        check("scaler stays within [min,max]", 300, |g: &mut Gen| {
            let mut c = cfg();
            c.min_replicas = g.usize(1..=3);
            c.max_replicas = c.min_replicas + g.usize(0..=7);
            c.step = g.usize(1..=4);
            c.scale_up_cooldown = Duration::from_secs_f64(g.f64(0.0, 10.0));
            c.scale_down_stabilization = Duration::from_secs_f64(g.f64(0.0, 30.0));
            let mut core = ScalerCore::new(c.clone(), 0.0);
            let mut current = g.usize(c.min_replicas..=c.max_replicas);
            let mut t = 0.0;
            for _ in 0..50 {
                t += g.f64(0.1, 5.0);
                let metric = g.f64(0.0, 0.5);
                if let Some(n) = core.evaluate(t, metric, current).target() {
                    assert!(
                        (c.min_replicas..=c.max_replicas).contains(&n),
                        "target {n} outside [{}, {}]",
                        c.min_replicas,
                        c.max_replicas
                    );
                    current = n;
                }
            }
        });
    }

    #[test]
    fn property_up_requires_over_threshold() {
        use crate::util::quick::{check, Gen};
        check("no scale-up at or under threshold", 300, |g: &mut Gen| {
            let c = cfg();
            let mut core = ScalerCore::new(c.clone(), 0.0);
            let mut t = 0.0;
            for _ in 0..50 {
                t += g.f64(0.1, 10.0);
                let metric = g.f64(0.0, c.threshold); // never above
                let d = core.evaluate(t, metric, 5);
                assert!(!matches!(d, Decision::Up(_)), "scaled up on {metric}");
            }
        });
    }

    #[test]
    fn per_model_hot_scales_cold_holds() {
        let mut p = PerModelPlanner::new(&pm_cfg(), &models(), 0.0);
        // hot per-replica demand 500 > 100, cold 20 < 100
        let changes = p.plan(
            0.0,
            &map_f64(&[("hot", 500.0), ("cold", 20.0)]),
            &map_usize(&[("hot", 1), ("cold", 1)]),
        );
        assert_eq!(changes, vec![("hot".to_string(), 2)]);
    }

    #[test]
    fn per_model_budget_caps_total() {
        let mut c = pm_cfg();
        c.max_replicas = 3; // budget: 3 pods across both models
        c.scale_up_cooldown = Duration::ZERO;
        let mut p = PerModelPlanner::new(&c, &models(), 0.0);
        // both hot; budget allows exactly one more pod, which must go to
        // the hotter model
        let changes = p.plan(
            0.0,
            &map_f64(&[("hot", 500.0), ("cold", 400.0)]),
            &map_usize(&[("hot", 1), ("cold", 1)]),
        );
        assert_eq!(changes, vec![("hot".to_string(), 2)]);
        // fleet at budget: nothing grows even under pressure
        let changes = p.plan(
            10.0,
            &map_f64(&[("hot", 500.0), ("cold", 400.0)]),
            &map_usize(&[("hot", 2), ("cold", 1)]),
        );
        assert!(changes.is_empty(), "{changes:?}");
    }

    #[test]
    fn per_model_down_frees_budget() {
        let mut c = pm_cfg();
        c.max_replicas = 4;
        c.scale_down_stabilization = Duration::from_secs(5);
        let mut p = PerModelPlanner::new(&c, &models(), 0.0);
        // Fleet at budget (4): hot's scale-up is rejected, cold's low
        // streak starts counting.
        let demand = map_f64(&[("hot", 900.0), ("cold", 0.0)]);
        let current = map_usize(&[("hot", 2), ("cold", 2)]);
        assert!(p.plan(0.0, &demand, &current).is_empty());
        // After the stabilization window, cold gives a pod back.
        let changes = p.plan(6.0, &demand, &current);
        assert!(
            changes.contains(&("cold".to_string(), 1)),
            "cold never scaled down: {changes:?}"
        );
    }

    #[test]
    fn per_model_bounds_respected() {
        let mut c = pm_cfg();
        c.scale_up_cooldown = Duration::ZERO;
        let mut p = PerModelPlanner::new(&c, &models(), 0.0);
        // at the per-model cap (4): hold even though demand is high
        let changes = p.plan(
            0.0,
            &map_f64(&[("hot", 900.0), ("cold", 20.0)]),
            &map_usize(&[("hot", 4), ("cold", 1)]),
        );
        assert!(changes.is_empty(), "{changes:?}");
        // at the per-model floor (1): hold even though demand is zero
        let mut p = PerModelPlanner::new(&c, &models(), 0.0);
        for t in 0..100 {
            let changes = p.plan(
                t as f64,
                &map_f64(&[("hot", 0.0), ("cold", 0.0)]),
                &map_usize(&[("hot", 1), ("cold", 1)]),
            );
            assert!(changes.is_empty(), "{changes:?}");
        }
    }

    #[test]
    fn property_down_spacing_at_least_stabilization() {
        use crate::util::quick::{check, Gen};
        check("downs spaced by stabilization window", 200, |g: &mut Gen| {
            let c = cfg();
            let stab = c.scale_down_stabilization.as_secs_f64();
            let mut core = ScalerCore::new(c, 0.0);
            let mut t = 0.0;
            let mut last_down: Option<f64> = None;
            let mut current = 8;
            for _ in 0..100 {
                t += g.f64(0.5, 3.0);
                let d = core.evaluate(t, 0.001, current);
                if let Decision::Down(n) = d {
                    if let Some(prev) = last_down {
                        assert!(
                            t - prev >= stab - 1e-9,
                            "downs {prev:.1} and {t:.1} closer than {stab}"
                        );
                    }
                    last_down = Some(t);
                    current = n;
                }
            }
        });
    }
}
