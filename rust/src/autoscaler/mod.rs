//! Load-based autoscaling — the KEDA analogue (§2.4).
//!
//! "KEDA is configured to launch additional Triton instances when a
//! user-defined metric exceeds a given threshold and, conversely, to shut
//! down servers when the metric value falls below the threshold. The
//! default scaling metric is defined as the average request queue latency
//! across Triton servers."
//!
//! Split into two layers:
//!
//! * [`ScalerCore`] — the pure decision function. Given (time, metric,
//!   current desired) it applies threshold / cooldown / stabilization /
//!   step / bounds rules and returns the new desired replica count. Being
//!   pure, it is exhaustively unit- and property-tested without threads.
//! * [`Autoscaler`] — the poll loop: samples the configured metric from
//!   the [`MetricStore`], feeds the core, and pushes decisions into the
//!   cluster's `desired_replicas` — exactly KEDA's relationship to a
//!   Deployment.

pub mod metric;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::AutoscalerConfig;
use crate::metrics::registry::{labels, Registry};
use crate::metrics::MetricStore;
use crate::orchestrator::Cluster;
use crate::util::clock::Clock;

pub use metric::MetricQuery;

/// A scaling decision from one evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Keep the current replica count.
    Hold,
    /// Scale up to the contained count.
    Up(usize),
    /// Scale down to the contained count.
    Down(usize),
}

impl Decision {
    /// The target replica count, if the decision changes it.
    pub fn target(&self) -> Option<usize> {
        match self {
            Decision::Hold => None,
            Decision::Up(n) | Decision::Down(n) => Some(*n),
        }
    }
}

/// Pure threshold/cooldown/stabilization state machine.
///
/// Scale-up: metric > `threshold`, rate-limited by `scale_up_cooldown`.
/// Scale-down: metric must stay below `threshold * scale_down_ratio` for a
/// full `scale_down_stabilization` window (KEDA's stabilization semantics:
/// any excursion above the low-water mark resets the window).
pub struct ScalerCore {
    cfg: AutoscalerConfig,
    /// Clock-seconds of the last scale-up.
    last_scale_up: f64,
    /// Start of the current below-low-water streak (None = streak broken).
    low_since: Option<f64>,
}

impl ScalerCore {
    /// Fresh core; `now` is the current clock time in seconds.
    pub fn new(cfg: AutoscalerConfig, now: f64) -> Self {
        ScalerCore {
            cfg,
            // Allow an immediate first scale-up.
            last_scale_up: now - 1e9,
            low_since: None,
        }
    }

    /// The configured bounds, clamped.
    fn clamp(&self, n: usize) -> usize {
        n.clamp(self.cfg.min_replicas, self.cfg.max_replicas)
    }

    /// Low-water mark below which scale-down stabilization accumulates.
    pub fn low_water(&self) -> f64 {
        self.cfg.threshold * self.cfg.scale_down_ratio
    }

    /// Evaluate one sample. `current` is the cluster's desired replicas.
    pub fn evaluate(&mut self, now: f64, metric: f64, current: usize) -> Decision {
        // Track the below-low-water streak regardless of what we decide.
        if metric < self.low_water() {
            if self.low_since.is_none() {
                self.low_since = Some(now);
            }
        } else {
            self.low_since = None;
        }

        if metric > self.cfg.threshold {
            if current >= self.cfg.max_replicas {
                return Decision::Hold;
            }
            if now - self.last_scale_up < self.cfg.scale_up_cooldown.as_secs_f64() {
                return Decision::Hold;
            }
            self.last_scale_up = now;
            return Decision::Up(self.clamp(current + self.cfg.step));
        }

        if let Some(since) = self.low_since {
            if current > self.cfg.min_replicas
                && now - since >= self.cfg.scale_down_stabilization.as_secs_f64()
            {
                // Restart the window so consecutive downs are spaced by a
                // full stabilization period each.
                self.low_since = Some(now);
                return Decision::Down(self.clamp(current.saturating_sub(self.cfg.step)));
            }
        }
        Decision::Hold
    }
}

/// The running autoscaler: poll loop + metrics.
pub struct Autoscaler {
    core: Arc<Mutex<ScalerCore>>,
    query: Arc<MetricQuery>,
    cluster: Arc<Cluster>,
    cfg: AutoscalerConfig,
    clock: Clock,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    m_metric: crate::metrics::registry::Gauge,
    m_scale_ups: crate::metrics::registry::Counter,
    m_scale_downs: crate::metrics::registry::Counter,
}

impl Autoscaler {
    /// Start polling `store` every `cfg.poll_interval` of clock time.
    pub fn start(
        cfg: AutoscalerConfig,
        cluster: Arc<Cluster>,
        store: MetricStore,
        clock: Clock,
        registry: Registry,
    ) -> Arc<Self> {
        let query = Arc::new(MetricQuery::parse(&cfg.metric, store, clock.clone()));
        let l = labels(&[]);
        let scaler = Arc::new(Autoscaler {
            core: Arc::new(Mutex::new(ScalerCore::new(cfg.clone(), clock.now_secs()))),
            query,
            cluster,
            cfg: cfg.clone(),
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            m_metric: registry.gauge("autoscaler_metric", &l),
            m_scale_ups: registry.counter("autoscaler_scale_ups_total", &l),
            m_scale_downs: registry.counter("autoscaler_scale_downs_total", &l),
        });
        if cfg.enabled {
            let s = Arc::clone(&scaler);
            let handle = std::thread::Builder::new()
                .name("autoscaler".into())
                .spawn(move || {
                    while !s.stop.load(Ordering::SeqCst) {
                        s.evaluate_once();
                        s.clock.sleep(s.cfg.poll_interval);
                    }
                })
                .expect("spawning autoscaler");
            *scaler.handle.lock().unwrap() = Some(handle);
        }
        scaler
    }

    /// One synchronous evaluation (used by the poll loop and by
    /// simulated-time tests). Returns the decision taken.
    pub fn evaluate_once(&self) -> Decision {
        let now = self.clock.now_secs();
        let Some(metric) = self.query.sample() else {
            return Decision::Hold; // no data yet
        };
        self.m_metric.set(metric);
        let current = self.cluster.desired();
        let decision = self.core.lock().unwrap().evaluate(now, metric, current);
        match decision {
            Decision::Up(n) => {
                log::info!(
                    "autoscaler: metric {metric:.4} > {:.4}, scaling {current} -> {n}",
                    self.cfg.threshold
                );
                self.m_scale_ups.inc();
                self.cluster.set_desired(n);
            }
            Decision::Down(n) => {
                log::info!("autoscaler: metric {metric:.4} low, scaling {current} -> {n}");
                self.m_scale_downs.inc();
                self.cluster.set_desired(n);
            }
            Decision::Hold => {}
        }
        decision
    }

    /// Latest sampled metric value.
    pub fn metric_value(&self) -> f64 {
        self.m_metric.get()
    }

    /// Stop the poll loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            enabled: true,
            metric: "queue_latency_avg".into(),
            threshold: 0.1,
            scale_down_ratio: 0.3, // low water 0.03
            min_replicas: 1,
            max_replicas: 10,
            poll_interval: Duration::from_secs(1),
            scale_up_cooldown: Duration::from_secs(5),
            scale_down_stabilization: Duration::from_secs(30),
            step: 1,
        }
    }

    #[test]
    fn scales_up_over_threshold() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(2));
    }

    #[test]
    fn cooldown_blocks_consecutive_ups() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(2));
        assert_eq!(core.evaluate(1.0, 0.5, 2), Decision::Hold);
        assert_eq!(core.evaluate(4.9, 0.5, 2), Decision::Hold);
        assert_eq!(core.evaluate(5.0, 0.5, 2), Decision::Up(3));
    }

    #[test]
    fn max_replicas_caps_up() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 10), Decision::Hold);
    }

    #[test]
    fn step_respected() {
        let mut c = cfg();
        c.step = 3;
        let mut core = ScalerCore::new(c, 0.0);
        assert_eq!(core.evaluate(0.0, 0.5, 1), Decision::Up(4));
        assert_eq!(core.evaluate(100.0, 0.5, 9), Decision::Up(10)); // clamped
    }

    #[test]
    fn scale_down_needs_full_stabilization() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        // below low water from t=0
        assert_eq!(core.evaluate(0.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(15.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(29.9, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(30.0, 0.01, 4), Decision::Down(3));
        // window restarts: next down only after another 30s
        assert_eq!(core.evaluate(31.0, 0.01, 3), Decision::Hold);
        assert_eq!(core.evaluate(60.0, 0.01, 3), Decision::Down(2));
    }

    #[test]
    fn excursion_resets_stabilization() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.01, 4), Decision::Hold);
        // metric pops above low water mid-window
        assert_eq!(core.evaluate(20.0, 0.05, 4), Decision::Hold);
        assert_eq!(core.evaluate(30.0, 0.01, 4), Decision::Hold); // streak restarted at 30
        assert_eq!(core.evaluate(59.0, 0.01, 4), Decision::Hold);
        assert_eq!(core.evaluate(60.0, 0.01, 4), Decision::Down(3));
    }

    #[test]
    fn never_below_min() {
        let mut core = ScalerCore::new(cfg(), 0.0);
        assert_eq!(core.evaluate(0.0, 0.0, 1), Decision::Hold);
        assert_eq!(core.evaluate(1000.0, 0.0, 1), Decision::Hold);
    }

    #[test]
    fn mid_band_holds() {
        // between low water (0.03) and threshold (0.1): no action, ever.
        let mut core = ScalerCore::new(cfg(), 0.0);
        for t in 0..200 {
            assert_eq!(core.evaluate(t as f64, 0.05, 4), Decision::Hold);
        }
    }

    #[test]
    fn property_bounds_always_respected() {
        use crate::util::quick::{check, Gen};
        check("scaler stays within [min,max]", 300, |g: &mut Gen| {
            let mut c = cfg();
            c.min_replicas = g.usize(1..=3);
            c.max_replicas = c.min_replicas + g.usize(0..=7);
            c.step = g.usize(1..=4);
            c.scale_up_cooldown = Duration::from_secs_f64(g.f64(0.0, 10.0));
            c.scale_down_stabilization = Duration::from_secs_f64(g.f64(0.0, 30.0));
            let mut core = ScalerCore::new(c.clone(), 0.0);
            let mut current = g.usize(c.min_replicas..=c.max_replicas);
            let mut t = 0.0;
            for _ in 0..50 {
                t += g.f64(0.1, 5.0);
                let metric = g.f64(0.0, 0.5);
                if let Some(n) = core.evaluate(t, metric, current).target() {
                    assert!(
                        (c.min_replicas..=c.max_replicas).contains(&n),
                        "target {n} outside [{}, {}]",
                        c.min_replicas,
                        c.max_replicas
                    );
                    current = n;
                }
            }
        });
    }

    #[test]
    fn property_up_requires_over_threshold() {
        use crate::util::quick::{check, Gen};
        check("no scale-up at or under threshold", 300, |g: &mut Gen| {
            let c = cfg();
            let mut core = ScalerCore::new(c.clone(), 0.0);
            let mut t = 0.0;
            for _ in 0..50 {
                t += g.f64(0.1, 10.0);
                let metric = g.f64(0.0, c.threshold); // never above
                let d = core.evaluate(t, metric, 5);
                assert!(!matches!(d, Decision::Up(_)), "scaled up on {metric}");
            }
        });
    }

    #[test]
    fn property_down_spacing_at_least_stabilization() {
        use crate::util::quick::{check, Gen};
        check("downs spaced by stabilization window", 200, |g: &mut Gen| {
            let c = cfg();
            let stab = c.scale_down_stabilization.as_secs_f64();
            let mut core = ScalerCore::new(c, 0.0);
            let mut t = 0.0;
            let mut last_down: Option<f64> = None;
            let mut current = 8;
            for _ in 0..100 {
                t += g.f64(0.5, 3.0);
                let d = core.evaluate(t, 0.001, current);
                if let Decision::Down(n) = d {
                    if let Some(prev) = last_down {
                        assert!(
                            t - prev >= stab - 1e-9,
                            "downs {prev:.1} and {t:.1} closer than {stab}"
                        );
                    }
                    last_down = Some(t);
                    current = n;
                }
            }
        });
    }
}
