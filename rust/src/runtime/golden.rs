//! Golden-file loader: deterministic input/output pairs written by
//! `python/compile/aot.py` so the Rust runtime can verify that the PJRT
//! execution of an artifact matches the JAX numerics bit-for-bit-ish.
//!
//! File format (`golden.b<N>.txt`):
//!
//! ```text
//!     input <d0> <d1> ...
//!     <flat values, whitespace separated>
//!     output <d0> <d1> ...
//!     <flat values>
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::Tensor;

/// A deterministic (input, expected output) pair for one artifact.
#[derive(Debug, Clone)]
pub struct Golden {
    pub input: Tensor,
    pub output: Tensor,
}

/// Parse one golden file.
pub fn load(path: &Path) -> Result<Golden> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading golden {}", path.display()))?;
    parse(&text)
}

/// Parse golden text (exposed for tests).
pub fn parse(text: &str) -> Result<Golden> {
    let mut lines = text.lines();
    let input = parse_tensor(&mut lines, "input")?;
    let output = parse_tensor(&mut lines, "output")?;
    Ok(Golden { input, output })
}

fn parse_tensor<'a, I: Iterator<Item = &'a str>>(lines: &mut I, expect: &str) -> Result<Tensor> {
    let header = lines.next().context("missing golden header line")?;
    let mut parts = header.split_whitespace();
    let name = parts.next().context("empty header")?;
    if name != expect {
        bail!("expected '{}' section, found '{}'", expect, name);
    }
    let shape: Vec<usize> = parts
        .map(|p| p.parse().context("bad dim"))
        .collect::<Result<_>>()?;
    let values = lines.next().context("missing golden values line")?;
    let data: Vec<f32> = values
        .split_whitespace()
        .map(|v| v.parse::<f32>().context("bad value"))
        .collect::<Result<_>>()?;
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let g = parse("input 2 2\n1 2 3 4\noutput 2\n0.5 -0.5\n").unwrap();
        assert_eq!(g.input.shape(), &[2, 2]);
        assert_eq!(g.output.data(), &[0.5, -0.5]);
    }

    #[test]
    fn wrong_section_rejected() {
        assert!(parse("output 1\n1\ninput 1\n1\n").is_err());
    }

    #[test]
    fn bad_counts_rejected() {
        assert!(parse("input 2 2\n1 2 3\noutput 1\n1\n").is_err());
    }
}
