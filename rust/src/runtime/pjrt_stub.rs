//! Stub replacement for the `xla` crate (PJRT bindings), compiled when the
//! `pjrt` cargo feature is off.
//!
//! The offline build image cannot install the `xla` crate (it downloads
//! the xla_extension native library), so every entry point here returns a
//! descriptive error at *runtime* while keeping the [`runtime`](super)
//! module compiling unchanged. Simulated-execution deployments
//! (`ExecutionMode::Simulated`) never reach these calls; real-execution
//! paths fail fast at `PjrtRuntime::cpu()` with an actionable message.
//!
//! The surface mirrors exactly the subset of the `xla` crate the runtime
//! uses — see `runtime/mod.rs` and `runtime/tensor.rs`.

use std::fmt;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "built without the `pjrt` cargo feature: real PJRT execution is \
         unavailable (use `server.execution: simulated`, or rebuild with \
         `--features pjrt` where the xla crate is installable)"
            .to_string(),
    )
}

type Result<T> = std::result::Result<T, Error>;

/// Stub `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always errors: no PJRT in this build.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name (unreachable: `cpu()` never succeeds).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Always errors.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always errors.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Constructible, but nothing can be done with it.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always errors.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Constructible so `Tensor::to_literal` type-checks; any further
    /// operation errors.
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    /// Always errors.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Always errors.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    /// Always errors.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Always errors.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub `xla::ArrayShape`.
pub struct ArrayShape;

impl ArrayShape {
    /// Unreachable (`array_shape` never succeeds); present for type-check.
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}
