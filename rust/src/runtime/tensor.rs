//! Minimal dense f32 tensor used on the request path.
//!
//! Requests carry raw little-endian f32 payloads plus a shape; this type is
//! the bridge between the RPC wire format and XLA literals. Only f32 is
//! needed — all three served models take and return f32 (see
//! `python/compile/model.py`).

use anyhow::{bail, Context, Result};

#[cfg(not(feature = "pjrt"))]
use crate::runtime::pjrt_stub as xla;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data; validates element count.
    ///
    /// The element count is computed with checked multiplication: shapes
    /// arrive straight off the wire (`codec::get_tensor`), and a hostile
    /// dim list like `[u32::MAX, u32::MAX, 2]` must come back as `Err`,
    /// not an overflow panic in debug builds.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let mut n: usize = 1;
        for &d in &shape {
            n = match n.checked_mul(d) {
                Some(n) => n,
                None => bail!("shape {:?} overflows the element count", shape),
            };
        }
        if n != data.len() {
            bail!(
                "shape {:?} implies {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Flat element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat data vector.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Leading (batch) dimension, or 0 for rank-0.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    /// Elements per batch row.
    pub fn row_len(&self) -> usize {
        if self.shape.is_empty() {
            0
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Slice out batch rows [start, start+count) as a new tensor.
    pub fn slice_rows(&self, start: usize, count: usize) -> Result<Tensor> {
        let b = self.batch();
        if start + count > b {
            bail!("row slice {}..{} out of batch {}", start, start + count, b);
        }
        let rl = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Ok(Tensor {
            shape,
            data: self.data[start * rl..(start + count) * rl].to_vec(),
        })
    }

    /// Stack tensors along the batch axis, padding with zero rows up to
    /// `target_batch`. All inputs must share trailing dims.
    pub fn stack_padded(parts: &[Tensor], target_batch: usize) -> Result<Tensor> {
        let first = parts.first().context("stack of zero tensors")?;
        let trailing = &first.shape[1..];
        let rl = first.row_len();
        let total: usize = parts.iter().map(|t| t.batch()).sum();
        if total > target_batch {
            bail!("stack total {} exceeds target batch {}", total, target_batch);
        }
        let mut data = Vec::with_capacity(target_batch * rl);
        for t in parts {
            if &t.shape[1..] != trailing {
                bail!(
                    "mismatched trailing dims {:?} vs {:?}",
                    &t.shape[1..],
                    trailing
                );
            }
            data.extend_from_slice(&t.data);
        }
        data.resize(target_batch * rl, 0.0);
        let mut shape = first.shape.clone();
        shape[0] = target_batch;
        Ok(Tensor { shape, data })
    }

    /// Convert to an XLA literal (f32, row-major).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .context("reshaping literal")?;
        Ok(lit)
    }

    /// Convert from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().context("literal to f32 vec")?;
        Tensor::new(dims, data)
    }

    /// Serialize as little-endian bytes (shape is carried separately).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Deserialize from little-endian bytes for a given shape.
    pub fn from_bytes(shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        if bytes.len() % 4 != 0 {
            bail!("payload length {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(shape, data)
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn new_rejects_overflowing_shape() {
        // Adversarial wire shapes must error, not panic on overflow.
        let huge = u32::MAX as usize;
        assert!(Tensor::new(vec![huge, huge, huge], vec![0.0; 4]).is_err());
        assert!(Tensor::new(vec![usize::MAX, 2], Vec::new()).is_err());
    }

    #[test]
    fn slice_rows_roundtrip() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|v| v as f32).collect()).unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_rows(3, 2).is_err());
    }

    #[test]
    fn stack_pads_with_zeros() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let s = Tensor::stack_padded(&[a, b], 4).unwrap();
        assert_eq!(s.shape(), &[4, 2]);
        assert_eq!(s.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn stack_rejects_mismatched_dims() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        assert!(Tensor::stack_padded(&[a, b], 4).is_err());
    }

    #[test]
    fn bytes_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1.5, -2.5, 3.25, 0.0]).unwrap();
        let b = t.to_bytes();
        let t2 = Tensor::from_bytes(vec![2, 2], &b).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn bad_payload_length_rejected() {
        assert!(Tensor::from_bytes(vec![1], &[0u8; 3]).is_err());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }
}
