//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! This is the only place the coordinator touches XLA. A [`PjrtRuntime`]
//! owns one PJRT CPU client per process; [`Engine`]s are compiled
//! executables for one (model, batch size) artifact; [`EngineSet`] groups
//! the batch-size variants of one model so the dynamic batcher can pick the
//! smallest compiled batch that fits.
//!
//! Python never runs here: the artifacts were produced once by
//! `make artifacts` (see `python/compile/aot.py`), and HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos — see DESIGN.md).

pub mod golden;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod pjrt_stub;
pub mod tensor;

#[cfg(not(feature = "pjrt"))]
use pjrt_stub as xla;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use tensor::Tensor;

/// Process-wide PJRT client wrapper.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    /// Name of the underlying PJRT platform (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_engine(&self, path: &Path, batch_size: usize) -> Result<Engine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Engine {
            exe,
            batch_size,
            path: path.display().to_string(),
        })
    }
}

/// A compiled executable for one (model, batch size) artifact.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    batch_size: usize,
    path: String,
}

impl Engine {
    /// The fixed batch size this engine was compiled for.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Artifact path (for logs/metrics labels).
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Execute on a single input tensor, returning the single output.
    ///
    /// The artifact was lowered with `return_tuple=True`, so the root is a
    /// 1-tuple which we unwrap here.
    pub fn execute(&self, input: &Tensor) -> Result<Tensor> {
        let lit = input.to_literal()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.path))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let inner = out.to_tuple1().context("unwrapping 1-tuple result")?;
        Tensor::from_literal(&inner)
    }
}

/// All compiled batch-size variants of one model.
pub struct EngineSet {
    name: String,
    engines: BTreeMap<usize, Arc<Engine>>,
}

impl EngineSet {
    /// Load every `model.b<N>.hlo.txt` in a model directory.
    pub fn load(runtime: &PjrtRuntime, model_dir: &Path, name: &str) -> Result<Self> {
        let mut engines = BTreeMap::new();
        for entry in std::fs::read_dir(model_dir)
            .with_context(|| format!("reading model dir {}", model_dir.display()))?
        {
            let path = entry?.path();
            let fname = match path.file_name().and_then(|f| f.to_str()) {
                Some(f) => f,
                None => continue,
            };
            if let Some(bs) = parse_artifact_batch(fname) {
                let engine = runtime.load_engine(&path, bs)?;
                engines.insert(bs, Arc::new(engine));
            }
        }
        if engines.is_empty() {
            bail!("no model.b<N>.hlo.txt artifacts in {}", model_dir.display());
        }
        Ok(EngineSet { name: name.to_string(), engines })
    }

    /// Model name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Sorted list of compiled batch sizes.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.engines.keys().copied().collect()
    }

    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        *self.engines.keys().last().unwrap()
    }

    /// Smallest compiled batch size >= `n`, or the max if `n` exceeds all
    /// (caller splits oversized batches).
    pub fn engine_for(&self, n: usize) -> Arc<Engine> {
        for (&bs, engine) in &self.engines {
            if bs >= n {
                return Arc::clone(engine);
            }
        }
        Arc::clone(self.engines.values().last().unwrap())
    }

    /// Engine for an exact batch size, if compiled.
    pub fn engine_exact(&self, n: usize) -> Option<Arc<Engine>> {
        self.engines.get(&n).cloned()
    }
}

/// Parse "model.b8.hlo.txt" -> Some(8).
pub fn parse_artifact_batch(fname: &str) -> Option<usize> {
    let rest = fname.strip_prefix("model.b")?;
    let rest = rest.strip_suffix(".hlo.txt")?;
    rest.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_artifact_names() {
        assert_eq!(parse_artifact_batch("model.b1.hlo.txt"), Some(1));
        assert_eq!(parse_artifact_batch("model.b16.hlo.txt"), Some(16));
        assert_eq!(parse_artifact_batch("config.yaml"), None);
        assert_eq!(parse_artifact_batch("model.bX.hlo.txt"), None);
        assert_eq!(parse_artifact_batch("golden.b1.txt"), None);
    }
}
