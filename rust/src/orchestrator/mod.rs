//! Cluster orchestrator — the Kubernetes analogue.
//!
//! SuperSONIC deploys onto Kubernetes clusters; no cluster exists in this
//! environment, so this module simulates the behaviours the paper's
//! results depend on (see DESIGN.md §Substitutions):
//!
//! * **capacity**: nodes expose GPU slots; a Triton pod binds one slot and
//!   pods beyond capacity stay `Pending`;
//! * **startup latency**: a scheduled pod passes through
//!   `Pending -> ContainerCreating -> Running`, taking the configured pod
//!   start delay (container pull) plus the server's model-load delay —
//!   this delay is what shapes the Fig. 2 scale-up ramp;
//! * **graceful termination**: scale-down drains an instance before
//!   freeing its GPU slot;
//! * **failure injection**: pod starts can fail with a configured
//!   probability and are retried (crash-loop style).
//!
//! The autoscaler interacts with the cluster exactly like KEDA does with a
//! Deployment: it sets `desired_replicas` and the reconcile loop converges
//! actual state toward it.

pub mod cluster;

pub use cluster::{Cluster, InstanceFactory, PodPhase, ReconcileHook};
