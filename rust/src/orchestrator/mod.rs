//! Cluster orchestrator — the Kubernetes analogue.
//!
//! SuperSONIC deploys onto Kubernetes clusters; no cluster exists in this
//! environment, so this module simulates the behaviours the paper's
//! results depend on (see DESIGN.md §Substitutions):
//!
//! * **capacity**: nodes expose GPU slots; a Triton pod binds one slot and
//!   pods beyond capacity stay `Pending`;
//! * **startup latency**: a scheduled pod passes through
//!   `Pending -> ContainerCreating -> Running`, taking the configured pod
//!   start delay (container pull) plus the server's model-load delay —
//!   this delay is what shapes the Fig. 2 scale-up ramp;
//! * **graceful termination**: scale-down drains an instance before
//!   freeing its GPU slot;
//! * **failure injection**: pod starts can fail with a configured
//!   probability and are retried (crash-loop style).
//!
//! The autoscaler interacts with the cluster exactly like KEDA does with a
//! Deployment: it sets `desired_replicas` and the reconcile loop converges
//! actual state toward it.
//!
//! Two scaling shapes are supported:
//!
//! * **global** ([`Cluster::start`]) — one `desired` replica count for the
//!   whole fleet, the base paper setup;
//! * **per-model** ([`Cluster::start_per_model`]) — one replica target per
//!   served model. Each pod carries the model it was spawned for as a
//!   *boot profile* (the instance boots advertising only that model), and
//!   the reconcile pass converges every model's pod group independently.
//!   The per-model autoscaler drives the targets through
//!   [`Cluster::set_desired_for`].
//!
//! Pods carry an accelerator class
//! ([`AcceleratorClass`](crate::engine::AcceleratorClass)) in their boot
//! profile: the classic fleet is `gpu`, and [`Cluster::start_with_cpu`]
//! (driven by `engines.cpu_replicas`) converges an additional `cpu` pod
//! group next to it — CPU pods advertise only CPU-capable backends, so
//! a heterogeneous fleet partitions by what each pod can actually run.
//!
//! Scale-down is placement-aware in both shapes: victim selection
//! ([`select_scale_down_victims`]) prefers pods whose advertised models
//! remain covered by at least the configured floor of other replicas, so
//! shrinking the fleet does not silently drop a model — youngest-first
//! only breaks ties among equally safe victims.

pub mod cluster;

pub use cluster::{
    select_scale_down_victims, Cluster, InstanceFactory, PodPhase, ReconcileHook,
};
