//! The cluster simulator (see module docs in `orchestrator/mod.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::config::ClusterConfig;
use crate::metrics::registry::{labels, Gauge, Counter, Registry};
use crate::server::Instance;
use crate::util::clock::Clock;
use crate::util::rng::Rng;

/// Pod lifecycle phase (Kubernetes naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PodPhase {
    /// Created, waiting for a free GPU slot.
    Pending,
    /// Bound to a slot; container pull + model load in progress.
    ContainerCreating,
    /// Serving; instance registered with the gateway.
    Running,
    /// Draining; slot freed when grace period elapses.
    Terminating,
}

/// Builds a (not yet Ready) [`Instance`] for a pod. The deployment layer
/// supplies this, closing over the model repository and metrics registry.
pub type InstanceFactory = Arc<dyn Fn(&str) -> Arc<Instance> + Send + Sync>;

/// Post-reconcile hook: invoked with the Ready endpoint snapshot after
/// every reconcile pass. The modelmesh placement controller hangs off
/// this — the cluster reconcile loop drives model placement exactly like
/// it drives pod lifecycle.
pub type ReconcileHook = Arc<dyn Fn(&[Arc<Instance>]) + Send + Sync>;

struct Pod {
    phase: PodPhase,
    /// (node, slot) once bound.
    slot: Option<(usize, usize)>,
    instance: Option<Arc<Instance>>,
    /// Clock-seconds when the current phase completes.
    phase_deadline: f64,
    /// Start attempts (failure injection retries).
    attempts: u32,
}

struct State {
    pods: BTreeMap<String, Pod>,
    /// free_slots[node] = set of free GPU indices.
    free_slots: Vec<Vec<usize>>,
    next_pod_id: usize,
    rng: Rng,
}

/// The simulated cluster plus its reconcile loop.
pub struct Cluster {
    cfg: ClusterConfig,
    startup_delay: Duration,
    clock: Clock,
    factory: InstanceFactory,
    desired: AtomicUsize,
    state: Mutex<State>,
    /// Ready instances, shared with the gateway's load balancer.
    endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
    stop: Arc<AtomicBool>,
    reconcile_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    hook: Mutex<Option<ReconcileHook>>,
    m_running: Gauge,
    m_desired: Gauge,
    m_pod_starts: Counter,
    m_pod_failures: Counter,
}

impl Cluster {
    /// Create the cluster and start its reconcile loop.
    ///
    /// `startup_delay` is the server's model-load time, added to the
    /// cluster's `pod_start_delay` (container pull) for every pod start.
    pub fn start(
        cfg: ClusterConfig,
        startup_delay: Duration,
        initial_replicas: usize,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        let free_slots = (0..cfg.nodes)
            .map(|_| (0..cfg.gpus_per_node).collect())
            .collect();
        let l = labels(&[]);
        let cluster = Arc::new(Cluster {
            cfg,
            startup_delay,
            clock: clock.clone(),
            factory,
            desired: AtomicUsize::new(initial_replicas),
            state: Mutex::new(State {
                pods: BTreeMap::new(),
                free_slots,
                next_pod_id: 0,
                rng: Rng::seeded(seed),
            }),
            endpoints: Arc::new(RwLock::new(Vec::new())),
            stop: Arc::new(AtomicBool::new(false)),
            reconcile_handle: Mutex::new(None),
            hook: Mutex::new(None),
            m_running: registry.gauge("replicas_running", &l),
            m_desired: registry.gauge("replicas_desired", &l),
            m_pod_starts: registry.counter("pod_starts_total", &l),
            m_pod_failures: registry.counter("pod_failures_total", &l),
        });
        let c = Arc::clone(&cluster);
        let handle = std::thread::Builder::new()
            .name("reconcile".into())
            .spawn(move || {
                while !c.stop.load(Ordering::SeqCst) {
                    c.reconcile();
                    c.clock.sleep(Duration::from_millis(200));
                }
            })
            .expect("spawning reconcile loop");
        *cluster.reconcile_handle.lock().unwrap() = Some(handle);
        cluster
    }

    /// Install the post-reconcile hook and fire it immediately with the
    /// current endpoints, so pods that became Running before the hook was
    /// attached are visible to it without waiting a reconcile period.
    pub fn set_reconcile_hook(&self, hook: ReconcileHook) {
        *self.hook.lock().unwrap() = Some(Arc::clone(&hook));
        hook(&self.endpoints());
    }

    /// Set the replica target (the KEDA/Deployment interface).
    pub fn set_desired(&self, n: usize) {
        self.desired.store(n, Ordering::SeqCst);
    }

    /// Current replica target.
    pub fn desired(&self) -> usize {
        self.desired.load(Ordering::SeqCst)
    }

    /// Ready instances (what the gateway routes to).
    pub fn endpoints(&self) -> Vec<Arc<Instance>> {
        self.endpoints.read().unwrap().clone()
    }

    /// Shared handle for the gateway's load balancer.
    pub fn endpoints_handle(&self) -> Arc<RwLock<Vec<Arc<Instance>>>> {
        Arc::clone(&self.endpoints)
    }

    /// Running pod count.
    pub fn running(&self) -> usize {
        self.endpoints.read().unwrap().len()
    }

    /// Phase of every pod, for introspection/tests.
    pub fn pod_phases(&self) -> BTreeMap<String, PodPhase> {
        let state = self.state.lock().unwrap();
        state.pods.iter().map(|(k, p)| (k.clone(), p.phase)).collect()
    }

    /// Total GPU slots in the cluster.
    pub fn capacity(&self) -> usize {
        self.cfg.nodes * self.cfg.gpus_per_node
    }

    /// Block until at least `n` instances are Ready (or timeout).
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> bool {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < timeout {
            if self.running() >= n {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        self.running() >= n
    }

    /// One reconcile pass (also callable directly by simulated-time tests).
    pub fn reconcile(&self) {
        let now = self.clock.now_secs();
        let mut to_stop: Vec<Arc<Instance>> = Vec::new();
        {
            let mut state = self.state.lock().unwrap();
            let desired = self.desired();

            // 1. Advance pod phases.
            let names: Vec<String> = state.pods.keys().cloned().collect();
            for name in names {
                let (phase, deadline) = {
                    let pod = state.pods.get(&name).unwrap();
                    (pod.phase, pod.phase_deadline)
                };
                match phase {
                    PodPhase::Pending => {
                        // try to bind a free slot
                        if let Some((node, slot)) = Self::take_slot(&mut state.free_slots) {
                            let delay = self.cfg.pod_start_delay + self.startup_delay;
                            let pod = state.pods.get_mut(&name).unwrap();
                            pod.slot = Some((node, slot));
                            pod.phase = PodPhase::ContainerCreating;
                            pod.phase_deadline = now + delay.as_secs_f64();
                        }
                    }
                    PodPhase::ContainerCreating if now >= deadline => {
                        let failed = {
                            let rate = self.cfg.pod_failure_rate;
                            rate > 0.0 && state.rng.chance(rate)
                        };
                        let pod = state.pods.get_mut(&name).unwrap();
                        if failed {
                            // crash-loop: back to the start of the phase
                            pod.attempts += 1;
                            pod.phase_deadline = now
                                + (self.cfg.pod_start_delay + self.startup_delay)
                                    .as_secs_f64();
                            self.m_pod_failures.inc();
                        } else {
                            let instance = (self.factory)(&name);
                            instance.mark_ready();
                            pod.instance = Some(Arc::clone(&instance));
                            pod.phase = PodPhase::Running;
                            self.endpoints.write().unwrap().push(instance);
                            self.m_pod_starts.inc();
                        }
                    }
                    PodPhase::Terminating if now >= deadline => {
                        let pod = state.pods.remove(&name).unwrap();
                        if let Some((node, slot)) = pod.slot {
                            state.free_slots[node].push(slot);
                        }
                        if let Some(inst) = pod.instance {
                            to_stop.push(inst);
                        }
                    }
                    _ => {}
                }
            }

            // 2. Converge replica count. Active = not Terminating.
            let active: Vec<String> = state
                .pods
                .iter()
                .filter(|(_, p)| p.phase != PodPhase::Terminating)
                .map(|(k, _)| k.clone())
                .collect();

            if active.len() < desired {
                for _ in 0..(desired - active.len()) {
                    let name = format!("triton-{}", state.next_pod_id);
                    state.next_pod_id += 1;
                    state.pods.insert(
                        name,
                        Pod {
                            phase: PodPhase::Pending,
                            slot: None,
                            instance: None,
                            phase_deadline: now,
                            attempts: 0,
                        },
                    );
                }
            } else if active.len() > desired {
                // Scale down: Pending first, then newest Running
                // (k8s-style youngest-first victim selection).
                let mut victims: Vec<String> = Vec::new();
                let mut pending: Vec<String> = active
                    .iter()
                    .filter(|n| state.pods[*n].phase != PodPhase::Running)
                    .cloned()
                    .collect();
                pending.sort();
                let mut running: Vec<String> = active
                    .iter()
                    .filter(|n| state.pods[*n].phase == PodPhase::Running)
                    .cloned()
                    .collect();
                // names are triton-<id>; sort by id descending = newest first
                running.sort_by_key(|n| {
                    std::cmp::Reverse(
                        n.rsplit('-').next().and_then(|s| s.parse::<usize>().ok()).unwrap_or(0),
                    )
                });
                victims.extend(pending);
                victims.extend(running);
                victims.truncate(active.len() - desired);

                for name in victims {
                    let phase = state.pods[&name].phase;
                    match phase {
                        PodPhase::Pending => {
                            state.pods.remove(&name);
                        }
                        PodPhase::ContainerCreating => {
                            // never became ready; free slot immediately
                            let pod = state.pods.remove(&name).unwrap();
                            if let Some((node, slot)) = pod.slot {
                                state.free_slots[node].push(slot);
                            }
                        }
                        PodPhase::Running => {
                            let pod = state.pods.get_mut(&name).unwrap();
                            pod.phase = PodPhase::Terminating;
                            pod.phase_deadline =
                                now + self.cfg.termination_grace.as_secs_f64();
                            if let Some(inst) = &pod.instance {
                                inst.drain();
                                let id = inst.id.clone();
                                self.endpoints
                                    .write()
                                    .unwrap()
                                    .retain(|e| e.id != id);
                            }
                        }
                        PodPhase::Terminating => {}
                    }
                }
            }

            self.m_desired.set(desired as f64);
        }
        self.m_running.set(self.running() as f64);
        // Join drained executors outside the lock.
        for inst in to_stop {
            inst.stop();
        }
        // Post-reconcile hook (model placement) over the fresh snapshot,
        // outside the state lock.
        let hook = self.hook.lock().unwrap().clone();
        if let Some(hook) = hook {
            hook(&self.endpoints());
        }
    }

    fn take_slot(free_slots: &mut [Vec<usize>]) -> Option<(usize, usize)> {
        // spread pods across nodes: pick the node with most free slots
        let node = free_slots
            .iter()
            .enumerate()
            .max_by_key(|(_, slots)| slots.len())
            .filter(|(_, slots)| !slots.is_empty())
            .map(|(i, _)| i)?;
        let slot = free_slots[node].pop()?;
        Some((node, slot))
    }

    /// Stop the reconcile loop and all instances.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.reconcile_handle.lock().unwrap().take() {
            let _ = h.join();
        }
        let instances: Vec<Arc<Instance>> = {
            let state = self.state.lock().unwrap();
            state.pods.values().filter_map(|p| p.instance.clone()).collect()
        };
        for inst in instances {
            inst.stop();
        }
        self.endpoints.write().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, ModelConfig};
    use crate::server::ModelRepository;
    use once_cell::sync::Lazy;

    // Lifecycle tests never execute engines: metadata-only is enough and
    // keeps them independent of the optional `pjrt` feature.
    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn factory(registry: Registry, clock: Clock) -> InstanceFactory {
        Arc::new(move |name: &str| {
            Instance::start_with_mode(
                name,
                Arc::clone(&REPO),
                &[ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() }],
                clock.clone(),
                registry.clone(),
                64,
                5.0,
                ExecutionMode::Simulated,
            )
        })
    }

    fn fast_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        }
    }

    #[test]
    fn boots_initial_replicas() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            1,
        );
        assert!(cluster.wait_ready(2, Duration::from_secs(5)));
        assert_eq!(cluster.running(), 2);
        cluster.shutdown();
    }

    #[test]
    fn reconcile_hook_sees_endpoint_churn() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            9,
        );
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        let seen = Arc::new(Mutex::new(0usize));
        let seen2 = Arc::clone(&seen);
        // Fires immediately on attach with the already-Running pod...
        cluster.set_reconcile_hook(Arc::new(move |eps| {
            let mut max = seen2.lock().unwrap();
            *max = (*max).max(eps.len());
        }));
        assert_eq!(*seen.lock().unwrap(), 1, "hook not fired on attach");
        // ...and follows scale-ups through the reconcile loop.
        cluster.set_desired(3);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(*seen.lock().unwrap(), 3, "hook missed new endpoints");
        cluster.shutdown();
    }

    #[test]
    fn scale_up_and_down() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            2,
        );
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        cluster.set_desired(3);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        cluster.set_desired(1);
        let t0 = std::time::Instant::now();
        while cluster.running() > 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(cluster.running(), 1);
        cluster.shutdown();
    }

    #[test]
    fn capacity_caps_running_pods() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(), // capacity 4
            Duration::from_millis(10),
            6,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            3,
        );
        assert!(cluster.wait_ready(4, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(cluster.running(), 4, "over capacity");
        // two pods must be parked Pending
        let pending = cluster
            .pod_phases()
            .values()
            .filter(|p| **p == PodPhase::Pending)
            .count();
        assert_eq!(pending, 2);
        cluster.shutdown();
    }

    #[test]
    fn startup_delay_observed() {
        let registry = Registry::new();
        let clock = Clock::real();
        let mut cfg = fast_cfg();
        cfg.pod_start_delay = Duration::from_millis(300);
        let cluster = Cluster::start(
            cfg,
            Duration::from_millis(0),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            4,
        );
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(cluster.running(), 0, "pod became Ready before its start delay");
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        cluster.shutdown();
    }

    #[test]
    fn failure_injection_retries() {
        let registry = Registry::new();
        let clock = Clock::real();
        let mut cfg = fast_cfg();
        cfg.pod_failure_rate = 0.5;
        cfg.pod_start_delay = Duration::from_millis(10);
        let cluster = Cluster::start(
            cfg,
            Duration::from_millis(0),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry.clone(), clock),
            5,
        );
        // with retries the pods must eventually come up
        assert!(cluster.wait_ready(2, Duration::from_secs(10)));
        cluster.shutdown();
    }

    #[test]
    fn terminated_instances_are_drained() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            6,
        );
        assert!(cluster.wait_ready(2, Duration::from_secs(5)));
        let eps = cluster.endpoints();
        cluster.set_desired(1);
        let t0 = std::time::Instant::now();
        while cluster.running() > 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        // one of the two previous endpoints must now be stopped
        std::thread::sleep(Duration::from_millis(200));
        let stopped = eps
            .iter()
            .filter(|i| i.state() == crate::server::InstanceState::Stopped)
            .count();
        assert_eq!(stopped, 1);
        cluster.shutdown();
    }
}
