//! The cluster simulator (see module docs in `orchestrator/mod.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::config::ClusterConfig;
use crate::engine::AcceleratorClass;
use crate::metrics::registry::{labels, Gauge, Counter, Registry};
use crate::server::Instance;
use crate::util::clock::Clock;
use crate::util::rng::Rng;

/// Pod lifecycle phase (Kubernetes naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PodPhase {
    /// Created, waiting for a free GPU slot.
    Pending,
    /// Bound to a slot; container pull + model load in progress.
    ContainerCreating,
    /// Serving; instance registered with the gateway.
    Running,
    /// Draining; slot freed when grace period elapses.
    Terminating,
}

/// Builds a (not yet Ready) [`Instance`] for a pod. The deployment layer
/// supplies this, closing over the model repository and metrics registry.
/// The second argument is the pod's boot profile: `Some(model)` when the
/// pod was spawned by per-model autoscaling for one specific model (the
/// instance should boot advertising only that model), `None` for generic
/// pods (the factory applies its default initial placement). The third
/// is the pod's accelerator class — the factory derives the instance's
/// backend set from it (`gpu` pods advertise PJRT, `cpu` pods only
/// CPU-capable backends).
pub type InstanceFactory =
    Arc<dyn Fn(&str, Option<&str>, AcceleratorClass) -> Arc<Instance> + Send + Sync>;

/// Post-reconcile hook: invoked with the Ready endpoint snapshot after
/// every reconcile pass. The modelmesh placement controller hangs off
/// this — the cluster reconcile loop drives model placement exactly like
/// it drives pod lifecycle.
pub type ReconcileHook = Arc<dyn Fn(&[Arc<Instance>]) + Send + Sync>;

struct Pod {
    phase: PodPhase,
    /// (node, slot) once bound.
    slot: Option<(usize, usize)>,
    instance: Option<Arc<Instance>>,
    /// Clock-seconds when the current phase completes.
    phase_deadline: f64,
    /// Start attempts (failure injection retries).
    attempts: u32,
    /// Boot profile: the model this pod was spawned for (per-model
    /// scaling), `None` for generic pods.
    profile: Option<String>,
    /// Accelerator class of the pod's slot (`gpu` for the classic
    /// fleet, `cpu` for `engines.cpu_replicas` pods).
    accel: AcceleratorClass,
}

struct State {
    pods: BTreeMap<String, Pod>,
    /// free_slots[node] = set of free GPU indices.
    free_slots: Vec<Vec<usize>>,
    next_pod_id: usize,
    rng: Rng,
}

/// The simulated cluster plus its reconcile loop.
pub struct Cluster {
    cfg: ClusterConfig,
    startup_delay: Duration,
    /// Pod-name prefix: empty for a single-cluster deployment,
    /// `"{site}-"` for a federated site's cluster, so pod (and therefore
    /// instance) names stay unique across the federation.
    pod_prefix: String,
    clock: Clock,
    factory: InstanceFactory,
    desired: AtomicUsize,
    /// CPU-class pod target (`engines.cpu_replicas`): a separate pod
    /// group converged next to the GPU groups in every mode. CPU pods
    /// never carry a model boot profile.
    cpu_desired: AtomicUsize,
    /// Per-model pod targets when per-model autoscaling drives the
    /// cluster (`None` = classic single global target). Each pod carries
    /// the model it was spawned for as its boot profile, and the
    /// reconcile pass converges every model group independently.
    model_desired: Mutex<Option<BTreeMap<String, usize>>>,
    /// Replica floor used by placement-aware victim selection: scale-down
    /// avoids victims that would leave any advertised model with fewer
    /// than this many Running replicas (the modelmesh
    /// `min_replicas_per_model`).
    victim_floor: AtomicUsize,
    /// (desired, running) gauges per model, populated in per-model mode.
    model_gauges: Mutex<BTreeMap<String, (Gauge, Gauge)>>,
    state: Mutex<State>,
    /// Ready instances, shared with the gateway's load balancer.
    endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
    stop: Arc<AtomicBool>,
    reconcile_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    hook: Mutex<Option<ReconcileHook>>,
    m_running: Gauge,
    m_desired: Gauge,
    m_pod_starts: Counter,
    m_pod_failures: Counter,
}

impl Cluster {
    /// Create the cluster and start its reconcile loop with one global
    /// replica target (the classic Deployment shape).
    ///
    /// `startup_delay` is the server's model-load time, added to the
    /// cluster's `pod_start_delay` (container pull) for every pod start.
    pub fn start(
        cfg: ClusterConfig,
        startup_delay: Duration,
        initial_replicas: usize,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        Self::start_inner(
            cfg,
            startup_delay,
            initial_replicas,
            0,
            None,
            None,
            clock,
            registry,
            factory,
            seed,
        )
    }

    /// [`Cluster::start`] with an additional CPU-class pod group
    /// (`engines.cpu_replicas`): `initial_cpu` pods boot with
    /// [`AcceleratorClass::Cpu`], advertising only CPU-capable backends.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_cpu(
        cfg: ClusterConfig,
        startup_delay: Duration,
        initial_replicas: usize,
        initial_cpu: usize,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        Self::start_inner(
            cfg,
            startup_delay,
            initial_replicas,
            initial_cpu,
            None,
            None,
            clock,
            registry,
            factory,
            seed,
        )
    }

    /// [`Cluster::start`] in per-model mode: one replica target per model
    /// (`targets`), each pod carrying its model as a boot profile. The
    /// per-model autoscaler drives the targets through
    /// [`Cluster::set_desired_for`].
    pub fn start_per_model(
        cfg: ClusterConfig,
        startup_delay: Duration,
        targets: BTreeMap<String, usize>,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        let initial = targets.values().sum();
        Self::start_inner(
            cfg,
            startup_delay,
            initial,
            0,
            Some(targets),
            None,
            clock,
            registry,
            factory,
            seed,
        )
    }

    /// [`Cluster::start_per_model`] as one federation site: pods are
    /// named `{site}-triton-N` (unique instance ids across sites), every
    /// cluster metric series carries a `site` label, and the site's CPU
    /// group boots alongside the per-model GPU groups.
    #[allow(clippy::too_many_arguments)]
    pub fn start_per_model_site(
        cfg: ClusterConfig,
        startup_delay: Duration,
        targets: BTreeMap<String, usize>,
        initial_cpu: usize,
        site: &str,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        let initial = targets.values().sum();
        Self::start_inner(
            cfg,
            startup_delay,
            initial,
            initial_cpu,
            Some(targets),
            Some(site),
            clock,
            registry,
            factory,
            seed,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        cfg: ClusterConfig,
        startup_delay: Duration,
        initial_replicas: usize,
        initial_cpu: usize,
        targets: Option<BTreeMap<String, usize>>,
        site: Option<&str>,
        clock: Clock,
        registry: Registry,
        factory: InstanceFactory,
        seed: u64,
    ) -> Arc<Self> {
        let free_slots = (0..cfg.nodes)
            .map(|_| (0..cfg.gpus_per_node).collect())
            .collect();
        let l = match site {
            None => labels(&[]),
            Some(site) => labels(&[("site", site)]),
        };
        let model_gauges: BTreeMap<String, (Gauge, Gauge)> = targets
            .iter()
            .flatten()
            .map(|(m, _)| {
                let ml = match site {
                    None => labels(&[("model", m)]),
                    Some(site) => labels(&[("model", m), ("site", site)]),
                };
                (
                    m.clone(),
                    (
                        registry.gauge("model_pods_desired", &ml),
                        registry.gauge("model_pods_running", &ml),
                    ),
                )
            })
            .collect();
        let cluster = Arc::new(Cluster {
            cfg,
            startup_delay,
            pod_prefix: site.map(|s| format!("{s}-")).unwrap_or_default(),
            clock: clock.clone(),
            factory,
            desired: AtomicUsize::new(initial_replicas),
            cpu_desired: AtomicUsize::new(initial_cpu),
            model_desired: Mutex::new(targets),
            victim_floor: AtomicUsize::new(1),
            model_gauges: Mutex::new(model_gauges),
            state: Mutex::new(State {
                pods: BTreeMap::new(),
                free_slots,
                next_pod_id: 0,
                rng: Rng::seeded(seed),
            }),
            endpoints: Arc::new(RwLock::new(Vec::new())),
            stop: Arc::new(AtomicBool::new(false)),
            reconcile_handle: Mutex::new(None),
            hook: Mutex::new(None),
            m_running: registry.gauge("replicas_running", &l),
            m_desired: registry.gauge("replicas_desired", &l),
            m_pod_starts: registry.counter("pod_starts_total", &l),
            m_pod_failures: registry.counter("pod_failures_total", &l),
        });
        let c = Arc::clone(&cluster);
        let handle = std::thread::Builder::new()
            .name("reconcile".into())
            .spawn(move || {
                while !c.stop.load(Ordering::SeqCst) {
                    c.reconcile();
                    c.clock.sleep(Duration::from_millis(200));
                }
            })
            .expect("spawning reconcile loop");
        *cluster.reconcile_handle.lock().unwrap() = Some(handle);
        cluster
    }

    /// Install the post-reconcile hook and fire it immediately with the
    /// current endpoints, so pods that became Running before the hook was
    /// attached are visible to it without waiting a reconcile period.
    pub fn set_reconcile_hook(&self, hook: ReconcileHook) {
        *self.hook.lock().unwrap() = Some(Arc::clone(&hook));
        hook(&self.endpoints());
    }

    /// Set the replica target (the KEDA/Deployment interface). Ignored
    /// (with a warning) in per-model mode, where
    /// [`Cluster::set_desired_for`] owns the targets.
    pub fn set_desired(&self, n: usize) {
        if self.model_desired.lock().unwrap().is_some() {
            log::warn!("set_desired({n}) ignored: cluster is in per-model mode");
            return;
        }
        self.desired.store(n, Ordering::SeqCst);
    }

    /// Current replica target: the global target, or the sum of the
    /// per-model targets in per-model mode. CPU-class pods are a
    /// separate group (see [`Cluster::cpu_desired`]) and do not count
    /// here — this is the autoscaler-facing GPU target.
    pub fn desired(&self) -> usize {
        match &*self.model_desired.lock().unwrap() {
            Some(targets) => targets.values().sum(),
            None => self.desired.load(Ordering::SeqCst),
        }
    }

    /// Set the CPU-class pod target (the `engines.cpu_replicas` group).
    pub fn set_cpu_desired(&self, n: usize) {
        self.cpu_desired.store(n, Ordering::SeqCst);
    }

    /// Current CPU-class pod target.
    pub fn cpu_desired(&self) -> usize {
        self.cpu_desired.load(Ordering::SeqCst)
    }

    /// Running CPU-class pods.
    pub fn running_cpu(&self) -> usize {
        let state = self.state.lock().unwrap();
        state
            .pods
            .values()
            .filter(|p| p.phase == PodPhase::Running && p.accel == AcceleratorClass::Cpu)
            .count()
    }

    /// Set one model's pod target (per-model mode only; unknown models
    /// and global mode are ignored with a warning).
    pub fn set_desired_for(&self, model: &str, n: usize) {
        let mut guard = self.model_desired.lock().unwrap();
        match guard.as_mut() {
            Some(targets) if targets.contains_key(model) => {
                targets.insert(model.to_string(), n);
            }
            _ => log::warn!("set_desired_for('{model}', {n}) ignored: no such target"),
        }
    }

    /// One model's pod target (0 when not in per-model mode).
    pub fn desired_for(&self, model: &str) -> usize {
        self.model_desired
            .lock()
            .unwrap()
            .as_ref()
            .and_then(|t| t.get(model).copied())
            .unwrap_or(0)
    }

    /// Is the cluster running per-model replica targets?
    pub fn per_model(&self) -> bool {
        self.model_desired.lock().unwrap().is_some()
    }

    /// Running pods spawned for `model` (boot-profile count; the serving
    /// replica count lives in the router, since placement may load more
    /// models onto a pod after boot).
    pub fn running_for(&self, model: &str) -> usize {
        let state = self.state.lock().unwrap();
        state
            .pods
            .values()
            .filter(|p| p.phase == PodPhase::Running && p.profile.as_deref() == Some(model))
            .count()
    }

    /// Floor for placement-aware scale-down victim selection (see
    /// [`select_scale_down_victims`]). Defaults to 1.
    pub fn set_victim_floor(&self, floor: usize) {
        self.victim_floor.store(floor, Ordering::SeqCst);
    }

    /// Ready instances (what the gateway routes to).
    pub fn endpoints(&self) -> Vec<Arc<Instance>> {
        self.endpoints.read().unwrap().clone()
    }

    /// Shared handle for the gateway's load balancer.
    pub fn endpoints_handle(&self) -> Arc<RwLock<Vec<Arc<Instance>>>> {
        Arc::clone(&self.endpoints)
    }

    /// Running pod count.
    pub fn running(&self) -> usize {
        self.endpoints.read().unwrap().len()
    }

    /// Phase of every pod, for introspection/tests.
    pub fn pod_phases(&self) -> BTreeMap<String, PodPhase> {
        let state = self.state.lock().unwrap();
        state.pods.iter().map(|(k, p)| (k.clone(), p.phase)).collect()
    }

    /// Total GPU slots in the cluster.
    pub fn capacity(&self) -> usize {
        self.cfg.nodes * self.cfg.gpus_per_node
    }

    /// Block until at least `n` instances are Ready (or timeout).
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> bool {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < timeout {
            if self.running() >= n {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        self.running() >= n
    }

    /// One reconcile pass (also callable directly by simulated-time tests).
    pub fn reconcile(&self) {
        let now = self.clock.now_secs();
        // Replica targets are read exactly ONCE per pass: this snapshot
        // feeds both the spawn counts and the victim counts below. An
        // autoscaler raising a target mid-pass must never make the victim
        // arithmetic see a different number than the spawn arithmetic
        // (momentary over-kill).
        let targets: Option<BTreeMap<String, usize>> =
            self.model_desired.lock().unwrap().clone();
        let cpu_want = self.cpu_desired.load(Ordering::SeqCst);
        let desired_total: usize = cpu_want
            + match &targets {
                Some(t) => t.values().sum::<usize>(),
                None => self.desired.load(Ordering::SeqCst),
            };
        let mut to_stop: Vec<Arc<Instance>> = Vec::new();
        {
            let mut state = self.state.lock().unwrap();

            // 1. Advance pod phases.
            let names: Vec<String> = state.pods.keys().cloned().collect();
            for name in names {
                let (phase, deadline) = {
                    let pod = state.pods.get(&name).unwrap();
                    (pod.phase, pod.phase_deadline)
                };
                match phase {
                    PodPhase::Pending => {
                        // try to bind a free slot
                        if let Some((node, slot)) = Self::take_slot(&mut state.free_slots) {
                            let delay = self.cfg.pod_start_delay + self.startup_delay;
                            let pod = state.pods.get_mut(&name).unwrap();
                            pod.slot = Some((node, slot));
                            pod.phase = PodPhase::ContainerCreating;
                            pod.phase_deadline = now + delay.as_secs_f64();
                        }
                    }
                    PodPhase::ContainerCreating if now >= deadline => {
                        let failed = {
                            let rate = self.cfg.pod_failure_rate;
                            rate > 0.0 && state.rng.chance(rate)
                        };
                        let pod = state.pods.get_mut(&name).unwrap();
                        if failed {
                            // crash-loop: back to the start of the phase
                            pod.attempts += 1;
                            pod.phase_deadline = now
                                + (self.cfg.pod_start_delay + self.startup_delay)
                                    .as_secs_f64();
                            self.m_pod_failures.inc();
                        } else {
                            let instance =
                                (self.factory)(&name, pod.profile.as_deref(), pod.accel);
                            instance.mark_ready();
                            pod.instance = Some(Arc::clone(&instance));
                            pod.phase = PodPhase::Running;
                            self.endpoints.write().unwrap().push(instance);
                            self.m_pod_starts.inc();
                        }
                    }
                    PodPhase::Terminating if now >= deadline => {
                        let pod = state.pods.remove(&name).unwrap();
                        if let Some((node, slot)) = pod.slot {
                            state.free_slots[node].push(slot);
                        }
                        if let Some(inst) = pod.instance {
                            to_stop.push(inst);
                        }
                    }
                    _ => {}
                }
            }

            // 2. Converge replica counts on the snapshot: every pod group
            // (one per model in per-model mode, a single global group
            // otherwise; the CPU-class group in every mode)
            // independently.
            match &targets {
                None => self.converge_group(
                    &mut state,
                    None,
                    AcceleratorClass::Gpu,
                    desired_total - cpu_want,
                    now,
                ),
                Some(t) => {
                    for (model, want) in t {
                        self.converge_group(
                            &mut state,
                            Some(model.as_str()),
                            AcceleratorClass::Gpu,
                            *want,
                            now,
                        );
                    }
                }
            }
            self.converge_group(&mut state, None, AcceleratorClass::Cpu, cpu_want, now);

            self.m_desired.set(desired_total as f64);
            if let Some(t) = &targets {
                let gauges = self.model_gauges.lock().unwrap();
                for (model, want) in t {
                    if let Some((g_desired, g_running)) = gauges.get(model) {
                        g_desired.set(*want as f64);
                        let running = state
                            .pods
                            .values()
                            .filter(|p| {
                                p.phase == PodPhase::Running
                                    && p.profile.as_deref() == Some(model.as_str())
                            })
                            .count();
                        g_running.set(running as f64);
                    }
                }
            }
        }
        self.m_running.set(self.running() as f64);
        // Join drained executors outside the lock.
        for inst in to_stop {
            inst.stop();
        }
        // Post-reconcile hook (model placement) over the fresh snapshot,
        // outside the state lock.
        let hook = self.hook.lock().unwrap().clone();
        if let Some(hook) = hook {
            hook(&self.endpoints());
        }
    }

    /// Converge one pod group (pods whose boot profile equals `profile`
    /// AND whose accelerator class equals `accel`) to `want` replicas:
    /// spawn the deficit, or pick and kill the surplus. Victim order:
    /// not-yet-Running pods first (they serve nothing), then
    /// placement-aware selection among Running pods (see
    /// [`select_scale_down_victims`]) — youngest-first only breaks ties.
    fn converge_group(
        &self,
        state: &mut State,
        profile: Option<&str>,
        accel: AcceleratorClass,
        want: usize,
        now: f64,
    ) {
        let group: Vec<String> = state
            .pods
            .iter()
            .filter(|(_, p)| {
                p.phase != PodPhase::Terminating
                    && p.profile.as_deref() == profile
                    && p.accel == accel
            })
            .map(|(k, _)| k.clone())
            .collect();

        if group.len() < want {
            for _ in 0..(want - group.len()) {
                let name = match accel {
                    AcceleratorClass::Gpu => {
                        format!("{}triton-{}", self.pod_prefix, state.next_pod_id)
                    }
                    AcceleratorClass::Cpu => {
                        format!("{}triton-cpu-{}", self.pod_prefix, state.next_pod_id)
                    }
                };
                state.next_pod_id += 1;
                state.pods.insert(
                    name,
                    Pod {
                        phase: PodPhase::Pending,
                        slot: None,
                        instance: None,
                        phase_deadline: now,
                        attempts: 0,
                        profile: profile.map(String::from),
                        accel,
                    },
                );
            }
            return;
        }
        if group.len() == want {
            return;
        }

        let excess = group.len() - want;
        let mut victims: Vec<String> = group
            .iter()
            .filter(|n| state.pods[*n].phase != PodPhase::Running)
            .cloned()
            .collect();
        victims.sort();
        victims.truncate(excess);

        if victims.len() < excess {
            // Candidates: this group's Running pods, youngest first (the
            // k8s default order, which the selection keeps for ties).
            // `loaded_models` is the WARM serving set: a copy mid-load
            // neither shields a victim nor counts as coverage, so the
            // selection never kills a model's last warm copy while its
            // replacement is still loading elsewhere.
            let mut candidates: Vec<(String, Vec<String>)> = group
                .iter()
                .filter(|n| state.pods[*n].phase == PodPhase::Running)
                .map(|n| {
                    let models = state.pods[n]
                        .instance
                        .as_ref()
                        .map(|i| i.loaded_models())
                        .unwrap_or_default();
                    (n.clone(), models)
                })
                .collect();
            candidates.sort_by_key(|(n, _)| {
                std::cmp::Reverse(
                    n.rsplit('-').next().and_then(|s| s.parse::<usize>().ok()).unwrap_or(0),
                )
            });
            // Coverage context: every other Running pod in the cluster
            // (other groups keep hosting models the victims drop).
            let candidate_names: std::collections::BTreeSet<&String> =
                candidates.iter().map(|(n, _)| n).collect();
            let others: Vec<Vec<String>> = state
                .pods
                .iter()
                .filter(|(n, p)| p.phase == PodPhase::Running && !candidate_names.contains(n))
                .map(|(_, p)| {
                    p.instance.as_ref().map(|i| i.loaded_models()).unwrap_or_default()
                })
                .collect();
            let floor = self.victim_floor.load(Ordering::SeqCst);
            victims.extend(select_scale_down_victims(
                &candidates,
                &others,
                excess - victims.len(),
                floor,
            ));
        }

        for name in victims {
            let phase = state.pods[&name].phase;
            match phase {
                PodPhase::Pending => {
                    state.pods.remove(&name);
                }
                PodPhase::ContainerCreating => {
                    // never became ready; free slot immediately
                    let pod = state.pods.remove(&name).unwrap();
                    if let Some((node, slot)) = pod.slot {
                        state.free_slots[node].push(slot);
                    }
                }
                PodPhase::Running => {
                    let pod = state.pods.get_mut(&name).unwrap();
                    pod.phase = PodPhase::Terminating;
                    pod.phase_deadline = now + self.cfg.termination_grace.as_secs_f64();
                    if let Some(inst) = &pod.instance {
                        inst.drain();
                        let id = inst.id.clone();
                        self.endpoints.write().unwrap().retain(|e| e.id != id);
                    }
                }
                PodPhase::Terminating => {}
            }
        }
    }

    fn take_slot(free_slots: &mut [Vec<usize>]) -> Option<(usize, usize)> {
        // spread pods across nodes: pick the node with most free slots
        let node = free_slots
            .iter()
            .enumerate()
            .max_by_key(|(_, slots)| slots.len())
            .filter(|(_, slots)| !slots.is_empty())
            .map(|(i, _)| i)?;
        let slot = free_slots[node].pop()?;
        Some((node, slot))
    }

    /// Stop the reconcile loop and all instances.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.reconcile_handle.lock().unwrap().take() {
            let _ = h.join();
        }
        let instances: Vec<Arc<Instance>> = {
            let state = self.state.lock().unwrap();
            state.pods.values().filter_map(|p| p.instance.clone()).collect()
        };
        for inst in instances {
            inst.stop();
        }
        self.endpoints.write().unwrap().clear();
    }
}

/// Placement-aware scale-down victim selection (pure, property-tested).
///
/// `candidates` are the killable Running pods in preference order
/// (callers pass youngest-first, the k8s default), each paired with the
/// models its instance advertises — the *warm* serving set only: a
/// replica still inside its warm-load window serves nothing, so it
/// neither protects a victim (coverage) nor is protected itself. A
/// candidate is *redundant* if killing it still leaves every model it
/// advertises with at least `floor` warm replicas across the remaining
/// pods; `others` are the warm serving sets of Running pods that are NOT
/// candidates (other scaling groups).
///
/// The selection kills redundant candidates while any exist; only when
/// every remaining candidate would push some model below the floor does
/// it fall back to the least-damaging one (fewest models pushed below
/// the floor, preference order breaking ties). The requested `count`
/// always wins — matching Deployment semantics, with the placement
/// controller's repair pass re-hosting whatever a forced kill dropped.
pub fn select_scale_down_victims(
    candidates: &[(String, Vec<String>)],
    others: &[Vec<String>],
    count: usize,
    floor: usize,
) -> Vec<String> {
    let mut coverage: BTreeMap<&str, usize> = BTreeMap::new();
    for models in candidates.iter().map(|(_, m)| m).chain(others.iter()) {
        for m in models {
            *coverage.entry(m.as_str()).or_insert(0) += 1;
        }
    }
    let mut remaining: Vec<usize> = (0..candidates.len()).collect();
    let mut victims = Vec::new();
    while victims.len() < count && !remaining.is_empty() {
        // Damage of killing candidate i: how many of its models drop
        // below the floor (coverage <= floor means the kill lands it at
        // floor - 1 or worse).
        let mut pick = 0usize;
        let mut pick_damage = usize::MAX;
        for (pos, &i) in remaining.iter().enumerate() {
            let damage = candidates[i]
                .1
                .iter()
                .filter(|m| coverage[m.as_str()] <= floor)
                .count();
            if damage < pick_damage {
                pick = pos;
                pick_damage = damage;
                if damage == 0 {
                    break; // first redundant candidate in preference order
                }
            }
        }
        let idx = remaining.remove(pick);
        for m in &candidates[idx].1 {
            *coverage.get_mut(m.as_str()).unwrap() -= 1;
        }
        victims.push(candidates[idx].0.clone());
    }
    victims
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, ModelConfig};
    use crate::server::ModelRepository;
    use once_cell::sync::Lazy;

    // Lifecycle tests never execute engines: metadata-only is enough and
    // keeps them independent of the optional `pjrt` feature.
    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn factory(registry: Registry, clock: Clock) -> InstanceFactory {
        Arc::new(move |name: &str, profile: Option<&str>, _accel: AcceleratorClass| {
            let inst = Instance::start_with_mode(
                name,
                Arc::clone(&REPO),
                &[ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() }],
                clock.clone(),
                registry.clone(),
                64,
                5.0,
                ExecutionMode::Simulated,
            );
            if let Some(model) = profile {
                inst.set_loaded_models(&[model.to_string()]);
            }
            inst
        })
    }

    fn fast_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(50),
            termination_grace: Duration::from_millis(20),
            pod_failure_rate: 0.0,
        }
    }

    #[test]
    fn boots_initial_replicas() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            1,
        );
        assert!(cluster.wait_ready(2, Duration::from_secs(5)));
        assert_eq!(cluster.running(), 2);
        cluster.shutdown();
    }

    #[test]
    fn reconcile_hook_sees_endpoint_churn() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            9,
        );
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        let seen = Arc::new(Mutex::new(0usize));
        let seen2 = Arc::clone(&seen);
        // Fires immediately on attach with the already-Running pod...
        cluster.set_reconcile_hook(Arc::new(move |eps| {
            let mut max = seen2.lock().unwrap();
            *max = (*max).max(eps.len());
        }));
        assert_eq!(*seen.lock().unwrap(), 1, "hook not fired on attach");
        // ...and follows scale-ups through the reconcile loop.
        cluster.set_desired(3);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(*seen.lock().unwrap(), 3, "hook missed new endpoints");
        cluster.shutdown();
    }

    #[test]
    fn scale_up_and_down() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            2,
        );
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        cluster.set_desired(3);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        cluster.set_desired(1);
        let t0 = std::time::Instant::now();
        while cluster.running() > 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(cluster.running(), 1);
        cluster.shutdown();
    }

    #[test]
    fn capacity_caps_running_pods() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(), // capacity 4
            Duration::from_millis(10),
            6,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            3,
        );
        assert!(cluster.wait_ready(4, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(cluster.running(), 4, "over capacity");
        // two pods must be parked Pending
        let pending = cluster
            .pod_phases()
            .values()
            .filter(|p| **p == PodPhase::Pending)
            .count();
        assert_eq!(pending, 2);
        cluster.shutdown();
    }

    #[test]
    fn startup_delay_observed() {
        let registry = Registry::new();
        let clock = Clock::real();
        let mut cfg = fast_cfg();
        cfg.pod_start_delay = Duration::from_millis(300);
        let cluster = Cluster::start(
            cfg,
            Duration::from_millis(0),
            1,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            4,
        );
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(cluster.running(), 0, "pod became Ready before its start delay");
        assert!(cluster.wait_ready(1, Duration::from_secs(5)));
        cluster.shutdown();
    }

    #[test]
    fn failure_injection_retries() {
        let registry = Registry::new();
        let clock = Clock::real();
        let mut cfg = fast_cfg();
        cfg.pod_failure_rate = 0.5;
        cfg.pod_start_delay = Duration::from_millis(10);
        let cluster = Cluster::start(
            cfg,
            Duration::from_millis(0),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry.clone(), clock),
            5,
        );
        // with retries the pods must eventually come up
        assert!(cluster.wait_ready(2, Duration::from_secs(10)));
        cluster.shutdown();
    }

    fn views(sets: &[(&str, &[&str])]) -> Vec<(String, Vec<String>)> {
        sets.iter()
            .map(|(n, ms)| (n.to_string(), ms.iter().map(|m| m.to_string()).collect()))
            .collect()
    }

    #[test]
    fn victim_selection_prefers_redundant() {
        // Youngest pod (first in preference order) is the sole host of
        // "rare"; the older pod's "common" is redundant via others.
        let candidates = views(&[("triton-9", &["rare"]), ("triton-1", &["common"])]);
        let others = vec![vec!["common".to_string()]];
        let victims = select_scale_down_victims(&candidates, &others, 1, 1);
        assert_eq!(victims, vec!["triton-1".to_string()]);
    }

    #[test]
    fn victim_selection_youngest_breaks_ties() {
        // Everyone redundant: the preference order (youngest first) wins.
        let candidates = views(&[("triton-3", &["m"]), ("triton-2", &["m"]), ("triton-1", &["m"])]);
        let victims = select_scale_down_victims(&candidates, &[], 2, 1);
        assert_eq!(victims, vec!["triton-3".to_string(), "triton-2".to_string()]);
    }

    #[test]
    fn victim_selection_forced_when_no_redundancy() {
        // Two pods, two singleton models: killing either drops a model
        // below the floor, but the requested count must still be met.
        let candidates = views(&[("triton-2", &["a"]), ("triton-1", &["b"])]);
        let victims = select_scale_down_victims(&candidates, &[], 1, 1);
        assert_eq!(victims.len(), 1);
    }

    #[test]
    fn victim_selection_tracks_earlier_kills() {
        // Two hosts of "a": after the first kill, the remaining "a" host
        // is no longer redundant, so the second kill must skip it and
        // take the "b" host (redundant via others) despite being older.
        let candidates =
            views(&[("triton-9", &["a"]), ("triton-8", &["a"]), ("triton-7", &["b"])]);
        let others = vec![vec!["b".to_string()]];
        let victims = select_scale_down_victims(&candidates, &others, 2, 1);
        assert_eq!(victims, vec!["triton-9".to_string(), "triton-7".to_string()]);
    }

    #[test]
    fn per_model_mode_converges_groups() {
        let registry = Registry::new();
        let clock = Clock::real();
        let targets: BTreeMap<String, usize> =
            [("icecube_cnn".to_string(), 2)].into_iter().collect();
        let cluster = Cluster::start_per_model(
            fast_cfg(),
            Duration::from_millis(10),
            targets,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            11,
        );
        assert!(cluster.per_model());
        assert_eq!(cluster.desired(), 2);
        assert!(cluster.wait_ready(2, Duration::from_secs(5)));
        assert_eq!(cluster.running_for("icecube_cnn"), 2);
        // every pod booted with its profile's serving set
        for inst in cluster.endpoints() {
            assert_eq!(inst.loaded_models(), vec!["icecube_cnn".to_string()]);
        }
        // raise the per-model target: group grows
        cluster.set_desired_for("icecube_cnn", 3);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        // global set_desired is inert in per-model mode
        cluster.set_desired(1);
        assert_eq!(cluster.desired(), 3);
        // shrink back down
        cluster.set_desired_for("icecube_cnn", 1);
        let t0 = std::time::Instant::now();
        while cluster.running() > 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(cluster.running(), 1);
        assert_eq!(cluster.desired_for("unknown_model"), 0);
        cluster.shutdown();
    }

    #[test]
    fn cpu_group_converges_next_to_gpu_group() {
        let registry = Registry::new();
        let clock = Clock::real();
        // Track the accelerator classes the factory saw, per pod name.
        let classes = Arc::new(Mutex::new(BTreeMap::<String, AcceleratorClass>::new()));
        let classes2 = Arc::clone(&classes);
        let base = factory(registry.clone(), clock.clone());
        let spy: InstanceFactory = Arc::new(move |name, profile, accel| {
            classes2.lock().unwrap().insert(name.to_string(), accel);
            base(name, profile, accel)
        });
        let cluster = Cluster::start_with_cpu(
            fast_cfg(), // capacity 4
            Duration::from_millis(10),
            2,
            1,
            clock,
            registry,
            spy,
            21,
        );
        assert_eq!(cluster.cpu_desired(), 1);
        assert!(cluster.wait_ready(3, Duration::from_secs(5)));
        assert_eq!(cluster.running_cpu(), 1);
        let classes = classes.lock().unwrap().clone();
        assert_eq!(
            classes.values().filter(|&&c| c == AcceleratorClass::Cpu).count(),
            1,
            "{classes:?}"
        );
        assert_eq!(
            classes.values().filter(|&&c| c == AcceleratorClass::Gpu).count(),
            2,
            "{classes:?}"
        );
        // the cpu group scales independently of the gpu target
        cluster.set_cpu_desired(2);
        assert!(cluster.wait_ready(4, Duration::from_secs(5)));
        assert_eq!(cluster.running_cpu(), 2);
        cluster.set_cpu_desired(0);
        let t0 = std::time::Instant::now();
        while cluster.running_cpu() > 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(cluster.running_cpu(), 0);
        assert_eq!(cluster.running(), 2, "gpu group disturbed by cpu scaling");
        cluster.shutdown();
    }

    #[test]
    fn terminated_instances_are_drained() {
        let registry = Registry::new();
        let clock = Clock::real();
        let cluster = Cluster::start(
            fast_cfg(),
            Duration::from_millis(10),
            2,
            clock.clone(),
            registry.clone(),
            factory(registry, clock),
            6,
        );
        assert!(cluster.wait_ready(2, Duration::from_secs(5)));
        let eps = cluster.endpoints();
        cluster.set_desired(1);
        let t0 = std::time::Instant::now();
        while cluster.running() > 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(20));
        }
        // one of the two previous endpoints must now be stopped
        std::thread::sleep(Duration::from_millis(200));
        let stopped = eps
            .iter()
            .filter(|i| i.state() == crate::server::InstanceState::Stopped)
            .count();
        assert_eq!(stopped, 1);
        cluster.shutdown();
    }
}
