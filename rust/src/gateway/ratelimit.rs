//! Rate limiting (§2.2: "Rate limiting regulates server load based on the
//! number of client connections or on an arbitrary external metric").
//!
//! Two cooperating mechanisms, both of which Envoy offers:
//!
//! * [`TokenBucket`] — classic requests-per-second limiting with a burst
//!   allowance, driven by the deployment [`Clock`] so time dilation in
//!   experiments applies to the refill rate too.
//! * [`PressureGate`] — "arbitrary external metric" limiting: a callback
//!   (typically a [`MetricStore`](crate::metrics::MetricStore) query, e.g.
//!   average queue latency) is sampled per request and requests are shed
//!   while the metric exceeds its threshold.

use std::sync::Mutex;

use crate::util::clock::Clock;

/// Clock-driven token bucket.
///
/// `rps = 0` disables limiting (every acquire succeeds).
pub struct TokenBucket {
    state: Mutex<BucketState>,
    rps: f64,
    burst: f64,
    clock: Clock,
}

struct BucketState {
    tokens: f64,
    /// Clock-seconds of the last refill.
    last: f64,
}

impl TokenBucket {
    /// Bucket allowing `rps` sustained requests/sec with `burst` capacity.
    pub fn new(rps: f64, burst: usize, clock: Clock) -> Self {
        TokenBucket {
            state: Mutex::new(BucketState { tokens: burst.max(1) as f64, last: clock.now_secs() }),
            rps,
            burst: burst.max(1) as f64,
            clock,
        }
    }

    /// Try to take one token; false = rate limited.
    pub fn try_acquire(&self) -> bool {
        self.try_acquire_reserving(0.0)
    }

    /// Try to take one token while leaving `reserve` tokens untouched in
    /// the bucket — the priority-aware acquire: bulk requests pass a
    /// positive reserve (a slice of the burst kept for higher classes),
    /// so as the bucket drains, bulk is limited first while standard and
    /// critical traffic still find tokens. `false` = rate limited.
    pub fn try_acquire_reserving(&self, reserve: f64) -> bool {
        if self.rps <= 0.0 {
            return true;
        }
        let now = self.clock.now_secs();
        let mut st = self.state.lock().unwrap();
        let elapsed = (now - st.last).max(0.0);
        st.tokens = (st.tokens + elapsed * self.rps).min(self.burst);
        st.last = now;
        if st.tokens >= 1.0 + reserve.max(0.0) {
            st.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Configured burst capacity.
    pub fn burst(&self) -> f64 {
        self.burst
    }

    /// Tokens currently available (for tests/metrics).
    pub fn available(&self) -> f64 {
        if self.rps <= 0.0 {
            return f64::INFINITY;
        }
        let now = self.clock.now_secs();
        let st = self.state.lock().unwrap();
        (st.tokens + (now - st.last).max(0.0) * self.rps).min(self.burst)
    }
}

/// Metric source sampled by the [`PressureGate`].
pub type PressureFn = Box<dyn Fn() -> f64 + Send + Sync>;

/// External-metric load shedding: open (accepting) while the sampled
/// metric stays at or below `threshold`.
pub struct PressureGate {
    source: PressureFn,
    threshold: f64,
}

impl PressureGate {
    /// Gate on `source() <= threshold`.
    pub fn new(source: PressureFn, threshold: f64) -> Self {
        PressureGate { source, threshold }
    }

    /// True when the request may proceed.
    pub fn admit(&self) -> bool {
        self.admit_scaled(1.0)
    }

    /// Priority-aware admit: the request proceeds while the metric stays
    /// at or below `threshold × factor`. Bulk passes a factor below 1
    /// (sheds first as pressure builds), critical a factor above 1
    /// (sheds last).
    pub fn admit_scaled(&self, factor: f64) -> bool {
        (self.source)() <= self.threshold * factor
    }

    /// Current metric reading (for logs/metrics).
    pub fn pressure(&self) -> f64 {
        (self.source)()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn zero_rps_never_limits() {
        let b = TokenBucket::new(0.0, 1, Clock::real());
        for _ in 0..10_000 {
            assert!(b.try_acquire());
        }
    }

    #[test]
    fn burst_then_limited() {
        let clock = Clock::simulated();
        let b = TokenBucket::new(10.0, 5, clock.clone());
        for _ in 0..5 {
            assert!(b.try_acquire());
        }
        assert!(!b.try_acquire(), "burst exhausted, no time passed");
    }

    #[test]
    fn refills_at_rps() {
        let clock = Clock::simulated();
        let b = TokenBucket::new(10.0, 5, clock.clone());
        for _ in 0..5 {
            assert!(b.try_acquire());
        }
        assert!(!b.try_acquire());
        clock.advance(Duration::from_millis(250)); // 2.5 tokens
        assert!(b.try_acquire());
        assert!(b.try_acquire());
        assert!(!b.try_acquire());
    }

    #[test]
    fn refill_caps_at_burst() {
        let clock = Clock::simulated();
        let b = TokenBucket::new(1000.0, 3, clock.clone());
        clock.advance(Duration::from_secs(60));
        assert!((b.available() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn sustained_rate_close_to_rps() {
        let clock = Clock::simulated();
        // burst 2 gives headroom so ns->f64 rounding cannot clip refills
        // at the cap.
        let b = TokenBucket::new(100.0, 2, clock.clone());
        let mut admitted = 0;
        for _ in 0..1000 {
            clock.advance(Duration::from_millis(5)); // 200/s offered
            if b.try_acquire() {
                admitted += 1;
            }
        }
        // 5 simulated seconds at 100 rps => ~500 admitted
        assert!((450..=551).contains(&admitted), "admitted {admitted}");
    }

    #[test]
    fn reserving_acquire_limits_bulk_first() {
        let clock = Clock::simulated();
        let b = TokenBucket::new(10.0, 8, clock.clone());
        // Drain to just above the reserve floor.
        for _ in 0..5 {
            assert!(b.try_acquire());
        }
        // 3 tokens left: a bulk acquire holding a 4-token reserve is
        // refused while an unreserved (standard/critical) acquire passes.
        assert!(!b.try_acquire_reserving(4.0), "bulk dipped into the reserve");
        assert!(b.try_acquire_reserving(0.0));
        // Refill restores bulk service.
        clock.advance(Duration::from_secs(1));
        assert!(b.try_acquire_reserving(4.0));
    }

    #[test]
    fn zero_rps_ignores_reserve() {
        let b = TokenBucket::new(0.0, 1, Clock::real());
        assert!(b.try_acquire_reserving(1000.0));
        assert!(b.burst() >= 1.0);
    }

    #[test]
    fn pressure_gate_scaled_admits_by_priority_factor() {
        let g = PressureGate::new(Box::new(|| 0.08), 0.05);
        // 0.08 > 0.05: standard sheds...
        assert!(!g.admit());
        // ...bulk shed even earlier (0.5x threshold)...
        assert!(!g.admit_scaled(0.5));
        // ...critical rides out 2x the threshold.
        assert!(g.admit_scaled(2.0));
    }

    #[test]
    fn pressure_gate_thresholds() {
        let v = Arc::new(AtomicU64::new(10));
        let v2 = Arc::clone(&v);
        let g = PressureGate::new(
            Box::new(move || v2.load(Ordering::SeqCst) as f64 / 1000.0),
            0.05,
        );
        assert!(g.admit()); // 0.010 <= 0.05
        v.store(80, Ordering::SeqCst);
        assert!(!g.admit()); // 0.080 > 0.05
        assert!((g.pressure() - 0.08).abs() < 1e-9);
    }
}
