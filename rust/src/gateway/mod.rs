//! The gateway — the Envoy Proxy analogue (§2.2).
//!
//! "A critical component of SuperSONIC is the Envoy Proxy, which acts as
//! the gateway between clients and inference servers." Clients see exactly
//! one endpoint (Fig. 1); behind it the gateway runs, per request:
//!
//! 1. **authentication** ([`auth`]) — HMAC token check when a deployment
//!    secret is configured;
//! 2. **rate limiting** ([`ratelimit`]) — a clock-driven token bucket
//!    and/or an external-metric pressure gate;
//! 3. **load balancing** ([`lb`]) — round-robin / least-connection /
//!    utilization-aware / random pick across Ready instances, with a
//!    per-instance in-flight cap for overload protection;
//! 4. **dispatch** — synchronous hand-off to the chosen instance's batch
//!    queue; the connection thread blocks, which gives per-connection
//!    backpressure exactly like a gRPC unary call.
//!
//! Every response carries the server-side latency breakdown
//! (queue/compute micros + folded batch size) and the gateway publishes
//! Prometheus-style metrics per status code.

pub mod auth;
pub mod lb;
pub mod pool;
pub mod ratelimit;

use std::net::SocketAddr;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;

use crate::config::{GatewayConfig, PriorityConfig, RpcConfig};
use crate::federation::FederationRouter;
use crate::metrics::registry::{labels, Registry};
use crate::modelmesh::ModelRouter;
use crate::rpc::codec::{InferRequest, InferResponse, Priority, RequestKind, Status};
use crate::rpc::server::{Handler, RpcServer, RpcServerOpts};
use crate::server::batcher::ExecOutcome;
use crate::server::Instance;
use crate::telemetry::{rollback, slo, Span, StageRecorder, Tracer, ROOT_SPAN};
use crate::util::clock::Clock;

use auth::Authenticator;
use lb::LoadBalancer;
use pool::SessionPool;
use ratelimit::{PressureGate, TokenBucket};

/// The running gateway: one TCP listener + the policy pipeline.
pub struct Gateway {
    server: Mutex<RpcServer>,
    addr: SocketAddr,
    lb: Arc<LoadBalancer>,
    /// Warm backend sessions, present when `rpc.remote_dispatch` is on.
    sessions: Option<Arc<SessionPool>>,
}

impl Gateway {
    /// Start the gateway over a live endpoint list (usually
    /// [`Cluster::endpoints_handle`](crate::orchestrator::Cluster::endpoints_handle)).
    ///
    /// `pressure` is the optional "arbitrary external metric" limiter; the
    /// deployment layer wires it to a metric-store query when configured.
    pub fn start(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
    ) -> Result<Self> {
        Self::start_with_router(cfg, endpoints, clock, registry, tracer, pressure, None)
    }

    /// [`Gateway::start`] with a model-aware routing table. When `router`
    /// is set, infer requests are routed through the per-model load
    /// balancer for `req.model` (the modelmesh path — "Envoy Proxy will
    /// be configured to extract model name from gRPC request body and
    /// use it to reroute the request to the load balancer corresponding
    /// to that model"); the global balancer still answers health probes.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_router(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
        router: Option<Arc<ModelRouter>>,
    ) -> Result<Self> {
        Self::start_with_priorities(
            cfg,
            endpoints,
            clock,
            registry,
            tracer,
            pressure,
            router,
            PriorityConfig::default(),
        )
    }

    /// [`Gateway::start_with_router`] with an explicit request-priority
    /// policy (`server.priorities`). The gateway resolves each request's
    /// class (explicit wire priority, else per-token / per-model /
    /// global defaults) and applies it at every shedding point: the
    /// token bucket keeps a reserve away from bulk, the pressure gate
    /// sheds bulk first and critical last, and the class rides to the
    /// instance's batcher lanes.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_priorities(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
        router: Option<Arc<ModelRouter>>,
        priorities: PriorityConfig,
    ) -> Result<Self> {
        Self::start_full(
            cfg,
            endpoints,
            clock,
            registry,
            tracer,
            pressure,
            router,
            priorities,
            &RpcConfig::default(),
        )
    }

    /// [`Gateway::start_with_priorities`] with an explicit `rpc` transport
    /// section. `rpc.dispatch_threads > 0` turns on demultiplexed dispatch
    /// at the listener (pipelined [`RpcSession`](crate::rpc::RpcSession)
    /// clients execute concurrently); `rpc.remote_dispatch` forwards
    /// routed requests to instances over their sonic-rpc endpoints
    /// through a warm [`SessionPool`] instead of the in-process submit.
    #[allow(clippy::too_many_arguments)]
    pub fn start_full(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
        router: Option<Arc<ModelRouter>>,
        priorities: PriorityConfig,
        rpc: &RpcConfig,
    ) -> Result<Self> {
        Self::start_inner(
            cfg, endpoints, clock, registry, tracer, pressure, router, priorities, rpc, None,
        )
    }

    /// [`Gateway::start_full`] as the federation-tier gateway: every
    /// infer request resolves and routes through `federation` — to the
    /// cheapest site with warm capacity for its model, spilling over on
    /// saturation — and a pick that lands at a remote site pays that
    /// site's WAN penalty before dispatch. `endpoints` is the gateway
    /// site's endpoint handle (health-probe fallback only; infer traffic
    /// never routes through the global balancer in federated mode).
    #[allow(clippy::too_many_arguments)]
    pub fn start_federated(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
        federation: Arc<FederationRouter>,
        priorities: PriorityConfig,
        rpc: &RpcConfig,
    ) -> Result<Self> {
        Self::start_inner(
            cfg,
            endpoints,
            clock,
            registry,
            tracer,
            pressure,
            None,
            priorities,
            rpc,
            Some(federation),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        cfg: &GatewayConfig,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        clock: Clock,
        registry: Registry,
        tracer: Tracer,
        pressure: Option<PressureGate>,
        router: Option<Arc<ModelRouter>>,
        priorities: PriorityConfig,
        rpc: &RpcConfig,
        fed: Option<Arc<FederationRouter>>,
    ) -> Result<Self> {
        let lb = Arc::new(LoadBalancer::new(
            cfg.lb_policy,
            endpoints,
            cfg.max_inflight_per_instance,
            0xC0FFEE,
        ));
        let authenticator = Arc::new(Authenticator::new(cfg.auth_secret.clone()));
        let bucket = Arc::new(TokenBucket::new(
            cfg.rate_limit_rps,
            cfg.rate_limit_burst,
            clock.clone(),
        ));
        let pressure = pressure.map(Arc::new);

        let m_requests = {
            let registry = registry.clone();
            move |status: Status| {
                registry.counter(
                    "gateway_requests_total",
                    &labels(&[("status", status.name())]),
                )
            }
        };
        let m_latency = registry.histogram("gateway_latency_seconds", &labels(&[]));
        // Per-model SLO feed: the burn-rate engine ([`slo::SloEngine`])
        // reads these to judge each model against its latency / error
        // targets. Latency is only observed for Ok responses (a shed
        // request has no service latency); every non-Ok infer counts as
        // an error against the model's budget.
        let m_model_latency = {
            let registry = registry.clone();
            move |model: &str| {
                registry.histogram(slo::MODEL_LATENCY_HIST, &labels(&[("model", model)]))
            }
        };
        let m_model_requests = {
            let registry = registry.clone();
            move |model: &str| {
                registry.counter(slo::MODEL_REQUESTS_COUNTER, &labels(&[("model", model)]))
            }
        };
        let m_model_errors = {
            let registry = registry.clone();
            move |model: &str| {
                registry.counter(slo::MODEL_ERRORS_COUNTER, &labels(&[("model", model)]))
            }
        };
        // Per-(model, version) feed for the canary rollback evaluator:
        // stamped only when version routing rewrote the request, labeled
        // with the base name + the concrete version it landed on.
        let m_version_requests = {
            let registry = registry.clone();
            move |model: &str, version: &str| {
                registry.counter(
                    rollback::VERSION_REQUESTS_COUNTER,
                    &labels(&[("model", model), ("version", version)]),
                )
            }
        };
        let m_version_latency = {
            let registry = registry.clone();
            move |model: &str, version: &str| {
                registry.histogram(
                    rollback::VERSION_LATENCY_HIST,
                    &labels(&[("model", model), ("version", version)]),
                )
            }
        };
        let m_version_errors = {
            let registry = registry.clone();
            move |model: &str, version: &str| {
                registry.counter(
                    rollback::VERSION_ERRORS_COUNTER,
                    &labels(&[("model", model), ("version", version)]),
                )
            }
        };
        let stage_recorder = StageRecorder::new(&registry);
        let m_shed = registry.counter("gateway_shed_total", &labels(&[]));
        let m_shed_priority: [_; Priority::COUNT] = [
            registry.counter("gateway_shed_priority_total", &labels(&[("priority", "bulk")])),
            registry
                .counter("gateway_shed_priority_total", &labels(&[("priority", "standard")])),
            registry
                .counter("gateway_shed_priority_total", &labels(&[("priority", "critical")])),
        ];

        let sessions = rpc
            .remote_dispatch
            .then(|| Arc::new(SessionPool::new(rpc.clone(), &registry)));

        let lb2 = Arc::clone(&lb);
        let clock2 = clock.clone();
        let sessions2 = sessions.clone();
        let handler: Handler = Arc::new(move |req: InferRequest| {
            let t0 = clock2.now();
            let ts0 = clock2.now_secs();
            let priority = priorities.resolve(req.priority, &req.token, &req.model);
            // Honor the wire head-sampling bit server-side: an opted-out
            // trace id is treated as untraced (0), so every span call on
            // this hop — and every hop it fans out to — no-ops.
            let trace = if req.sampled { req.trace_id } else { 0 };
            let is_infer = req.kind == RequestKind::Infer;
            let model = req.model.clone();
            // Version routing: rewrite an unversioned infer request to
            // the concrete versioned pool it should hit (pinned ->
            // canary split -> incumbent, with warm-replica fallback).
            // The SLO feed below keeps the client-facing base name.
            let mut req = req;
            if is_infer {
                if let Some(f) = fed.as_deref() {
                    let routed = f.resolve(&req.model);
                    if routed != req.model {
                        req.model = routed;
                    }
                } else if let Some(r) = router.as_deref() {
                    let routed = r.resolve(&req.model);
                    if routed != req.model {
                        req.model = routed;
                    }
                }
            }
            let routed_model = req.model.clone();
            let mut serving_site: Option<String> = None;
            let response = handle_request(
                req,
                trace,
                priority,
                &priorities,
                &lb2,
                router.as_deref(),
                fed.as_deref(),
                &clock2,
                &authenticator,
                &bucket,
                pressure.as_deref(),
                &tracer,
                sessions2.as_deref(),
                &mut serving_site,
            );
            let dt = (clock2.now().saturating_sub(t0)) as f64 / 1e9;
            m_latency.observe(dt);
            m_requests(response.status).inc();
            if is_infer {
                m_model_requests(&model).inc();
                if response.status == Status::Ok {
                    m_model_latency(&model).observe(dt);
                } else {
                    m_model_errors(&model).inc();
                }
                if let (base, Some(v)) = crate::server::split_version(&routed_model) {
                    let version = format!("v{v}");
                    m_version_requests(base, &version).inc();
                    if response.status == Status::Ok {
                        m_version_latency(base, &version).observe(dt);
                    } else {
                        m_version_errors(base, &version).inc();
                    }
                }
            }
            if matches!(
                response.status,
                Status::RateLimited | Status::Overloaded | Status::Unauthorized
            ) {
                m_shed.inc();
                m_shed_priority[priority.index()].inc();
            }
            if trace != 0 && tracer.enabled() {
                // Close the root span over the whole pipeline, then fold
                // the finished trace into the per-stage histograms —
                // attributed to the serving site when the request was
                // routed by the federation layer, so a spilled request's
                // wan stage lands on the site that actually served it.
                tracer.record(Span {
                    trace_id: trace,
                    name: ROOT_SPAN.into(),
                    start: ts0,
                    end: clock2.now_secs(),
                });
                let view = tracer.trace(trace);
                match serving_site.as_deref() {
                    Some(site) => stage_recorder.observe_from(&view, site),
                    None => stage_recorder.observe(&view),
                }
            }
            response
        });

        let server = RpcServer::start_with_opts(
            &cfg.listen,
            RpcServerOpts {
                workers: cfg.worker_threads,
                max_connections: cfg.max_connections,
                max_inflight_per_conn: rpc.max_inflight_per_conn,
                dispatch_threads: rpc.dispatch_threads,
            },
            handler,
        )?;
        let addr = server.addr();
        Ok(Gateway { server: Mutex::new(server), addr, lb, sessions })
    }

    /// Bound address (resolves `:0` ephemeral listens).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Routable (Ready) endpoint count, as the balancer sees it.
    pub fn healthy_endpoints(&self) -> usize {
        self.lb.healthy_count()
    }

    /// Open client connections.
    pub fn open_connections(&self) -> u64 {
        self.server.lock().unwrap().open_connections()
    }

    /// The backend session pool (present iff `rpc.remote_dispatch`).
    pub fn session_pool(&self) -> Option<&SessionPool> {
        self.sessions.as_deref()
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(&self) {
        self.server.lock().unwrap().shutdown();
    }
}

/// The per-request policy pipeline. `priority` is the request's resolved
/// class (explicit wire priority or a `server.priorities` default);
/// `trace` is the effective trace id (0 when untraced or head-sampled
/// out), stamped on every stage span and propagated to the instance.
/// `serving_site` reports the federated site of the final pick back to
/// the caller (left `None` outside federation) so the finished trace can
/// be attributed to the site that served it.
#[allow(clippy::too_many_arguments)]
fn handle_request(
    req: InferRequest,
    trace: u64,
    priority: Priority,
    priorities: &PriorityConfig,
    lb: &LoadBalancer,
    router: Option<&ModelRouter>,
    fed: Option<&FederationRouter>,
    clock: &Clock,
    authenticator: &Authenticator,
    bucket: &TokenBucket,
    pressure: Option<&PressureGate>,
    tracer: &Tracer,
    sessions: Option<&SessionPool>,
    serving_site: &mut Option<String>,
) -> InferResponse {
    // 0. Health probes bypass auth/limits: they answer "is the deployment
    //    routable" (the k8s readiness probe analogue). Federated, that
    //    means "is anything ready at ANY site".
    if req.kind == RequestKind::Health {
        let healthy = match fed {
            Some(f) => f.ready(),
            None => lb.healthy_count() > 0,
        };
        return if healthy {
            InferResponse::ok(req.request_id, crate::runtime::Tensor::zeros(vec![0]))
        } else {
            InferResponse::err(req.request_id, Status::Overloaded, "no ready instances")
        };
    }

    // 1. Authentication.
    let admitted = {
        let _stage = tracer.span(trace, "admit");
        authenticator.check(&req.token)
    };
    if !admitted {
        return InferResponse::err(req.request_id, Status::Unauthorized, "invalid token");
    }

    // 2. Rate limiting: token bucket, then external-metric gate — both
    //    priority-aware, so bulk sheds first at the gate. Bulk acquires
    //    leave a slice of the burst in reserve for higher classes;
    //    the gate threshold scales down for bulk and up for critical.
    // The reserve is clamped to burst - 1 so bulk always keeps at least
    // one usable token in a full bucket: a tiny burst with the default
    // reserve must rate-limit bulk *first*, never *forever*.
    let ratelimit_stage = tracer.span(trace, "ratelimit");
    let reserve = if priority == Priority::Bulk {
        (bucket.burst() * priorities.bulk_reserve).min(bucket.burst() - 1.0).max(0.0)
    } else {
        0.0
    };
    if !bucket.try_acquire_reserving(reserve) {
        return InferResponse::err(
            req.request_id,
            Status::RateLimited,
            format!("rate limit exceeded ({} class)", priority.name()),
        );
    }
    if let Some(gate) = pressure {
        if !gate.admit_scaled(priorities.pressure_factor(priority)) {
            return InferResponse::err(
                req.request_id,
                Status::RateLimited,
                format!(
                    "load shedding: pressure {:.4} over the {} threshold",
                    gate.pressure(),
                    priority.name()
                ),
            );
        }
    }
    drop(ratelimit_stage);

    // 3. Route. One retry on a *different* instance if the first pick
    //    rejects (it may have saturated between pick and submit) — the
    //    retry excludes the instance that rejected. The rejected submit
    //    hands the tensor back, so no per-request clone. With a model
    //    router the pick goes through the per-model balancer for
    //    `req.model`; a ModelNotFound rejection from an instance is then
    //    a stale-pool race (the model was just unloaded), so the retry
    //    picks a fresh replica instead of giving up.
    let mut input = req.input;
    let mut last_status = Status::Overloaded;
    let mut last_msg = String::from("no ready instances");
    let mut rejected_by: Option<String> = None;
    for attempt in 0..2 {
        // Each routing hop gets its own span — the first is "route", a
        // second attempt is "retry" — covering pick + submit hand-off
        // (the wait for the executor's reply is queue/compute time,
        // reported by the server-side spans). A cross-site WAN hop gets
        // its own site-attributed "wan" span BETWEEN the pick and the
        // dispatch, outside both hop spans, so stage durations still sum
        // to the root span.
        let hop_name = if attempt == 0 { "route" } else { "retry" };
        let pick_stage = tracer.span(trace, hop_name);
        let no_replica_msg = |status: Status, rejected_by: &Option<String>, last: Status| match status
        {
            Status::ModelNotFound => {
                format!("model '{}' not in the serving catalog", req.model)
            }
            _ => match rejected_by {
                None => format!("no replica for model '{}' accepting work", req.model),
                Some(id) => format!(
                    "no other replica for model '{}' after instance {id} rejected: {}",
                    req.model,
                    last.name()
                ),
            },
        };
        let (instance, wan) = match (fed, router) {
            // Federated: site-aware pick; a remote-site hop carries the
            // configured WAN penalty back for the dispatch below.
            (Some(f), _) => match f.pick_excluding(&req.model, rejected_by.as_deref()) {
                Ok(pick) => {
                    *serving_site = Some(pick.site);
                    (pick.instance, pick.wan)
                }
                Err(status) => {
                    last_msg = no_replica_msg(status, &rejected_by, last_status);
                    last_status = status;
                    break;
                }
            },
            (None, Some(r)) => match r.pick_excluding(&req.model, rejected_by.as_deref()) {
                Ok(inst) => (inst, Duration::ZERO),
                Err(status) => {
                    last_msg = no_replica_msg(status, &rejected_by, last_status);
                    last_status = status;
                    break;
                }
            },
            (None, None) => match lb.pick_excluding(rejected_by.as_deref()) {
                Some(inst) => (inst, Duration::ZERO),
                None => {
                    // No routable replica on THIS attempt: report that,
                    // not a stale earlier rejection (a retry that finds
                    // the fleet gone must not blame the first instance).
                    last_msg = match &rejected_by {
                        None => "no ready instances".into(),
                        Some(id) => format!(
                            "no other ready instance for retry (instance {id} rejected: {})",
                            last_status.name()
                        ),
                    };
                    last_status = Status::Overloaded;
                    break;
                }
            },
        };
        // WAN penalty: a request spilled to a remote site pays the
        // inter-site latency before the hand-off (both directions are
        // folded into the one configured cost). The hop is recorded as
        // a "wan" span attributed to the serving site — the span guard
        // carries a site-scoped tracer facade so the cross-site leg of
        // a spilled request shows up in its stage breakdown.
        drop(pick_stage);
        if wan > Duration::ZERO {
            let _wan_stage = serving_site
                .as_deref()
                .and_then(|site| tracer.for_site(site).span(trace, "wan"));
            clock.sleep(wan);
        }
        let hop_stage = tracer.span(trace, hop_name);
        // Remote dispatch: when the session pool is on and the instance
        // advertises a sonic-rpc endpoint, forward over the wire instead
        // of the in-process submit. The request's resolved metadata rides
        // the frame — priority class, effective trace id + sampling bit,
        // auth token — so the backend sees exactly what this hop saw.
        if let (Some(sess_pool), Some(backend)) = (sessions, instance.rpc_addr()) {
            let fwd = InferRequest {
                kind: RequestKind::Infer,
                request_id: 0, // the session stamps its own wire id
                trace_id: trace,
                sampled: trace != 0,
                token: req.token.clone(),
                model: req.model.clone(),
                priority: Some(priority),
                input,
            };
            let hop = remote_hop(
                sess_pool,
                &backend,
                &fwd,
                router.is_some() || fed.is_some(),
                req.request_id,
                &instance.id,
            );
            match hop {
                RemoteHop::Done(resp) => {
                    drop(hop_stage);
                    return resp;
                }
                RemoteHop::Retry { status, msg } => {
                    input = fwd.input; // hand the tensor back for the retry
                    last_status = status;
                    last_msg = msg;
                    rejected_by = Some(instance.id.clone());
                    continue;
                }
            }
        }
        match instance.submit_prio(&req.model, input, priority, trace) {
            Ok(rx) => {
                drop(hop_stage);
                let outcome = rx.recv().unwrap_or(ExecOutcome::Err {
                    status: Status::Internal,
                    message: "executor dropped request".into(),
                });
                return finish(req.request_id, outcome);
            }
            Err((status, returned)) => {
                input = returned;
                last_status = status;
                last_msg = format!("instance {} rejected: {}", instance.id, status.name());
                rejected_by = Some(instance.id.clone());
                // Model/shape errors fail identically everywhere — except
                // a router-mode ModelNotFound, which can be a stale pool.
                let terminal = match status {
                    Status::BadRequest => true,
                    Status::ModelNotFound => router.is_none() && fed.is_none(),
                    _ => false,
                };
                if terminal {
                    break;
                }
            }
        }
    }
    InferResponse::err(req.request_id, last_status, last_msg)
}

/// Outcome of one networked backend hop.
enum RemoteHop {
    /// A final answer for the client (success or a terminal error).
    Done(InferResponse),
    /// The hop failed in a way the route loop may retry elsewhere.
    Retry { status: Status, msg: String },
}

/// Forward one routed request to `addr` over a pooled session. Transport
/// failures (pool exhausted, dial/write failure, io timeout, dead
/// session) come back as retryable `Overloaded` — the backend may be
/// gone but its peers are not. Backend *responses* are final except
/// `Overloaded` (it saturated between pick and dispatch) and a
/// router-mode `ModelNotFound` (stale pool: the model just unloaded).
fn remote_hop(
    pool: &SessionPool,
    addr: &str,
    fwd: &InferRequest,
    router_mode: bool,
    client_id: u64,
    instance_id: &str,
) -> RemoteHop {
    let session = match pool.checkout(addr) {
        Ok(s) => s,
        Err(e) => {
            return RemoteHop::Retry {
                status: Status::Overloaded,
                msg: format!("instance {instance_id}: {e:#}"),
            }
        }
    };
    let result = session.call(fwd);
    if session.is_closed() {
        pool.evict_closed(addr);
    }
    match result {
        Ok(mut resp) => {
            // The backend answered under the session's wire id; restore
            // the client's id before the response leaves the gateway.
            resp.request_id = client_id;
            let retryable = resp.status == Status::Overloaded
                || (resp.status == Status::ModelNotFound && router_mode);
            if retryable {
                RemoteHop::Retry {
                    status: resp.status,
                    msg: format!("instance {instance_id} rejected: {}", resp.status.name()),
                }
            } else {
                RemoteHop::Done(resp)
            }
        }
        Err(e) => {
            pool.note_transport_error();
            RemoteHop::Retry {
                status: Status::Overloaded,
                msg: format!("instance {instance_id} rpc hop failed: {e:#}"),
            }
        }
    }
}

/// Convert an executor outcome into a wire response. Tracing spans are
/// no longer synthesized here: the batcher and executor record real
/// queue/batch/compute spans against the propagated trace id, and the
/// handler closes the root span around the whole pipeline.
fn finish(request_id: u64, outcome: ExecOutcome) -> InferResponse {
    match outcome {
        ExecOutcome::Ok { output, queue_us, compute_us, batch_rows } => InferResponse {
            status: Status::Ok,
            request_id,
            queue_us,
            compute_us,
            batch_size: batch_rows,
            output,
            error: String::new(),
        },
        ExecOutcome::Err { status, message } => InferResponse::err(request_id, status, message),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, ModelConfig, ServiceModelConfig};
    use crate::rpc::client::RpcClient;
    use crate::runtime::Tensor;
    use crate::server::ModelRepository;
    use once_cell::sync::Lazy;
    use std::time::Duration;

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn sim_instance(id: &str, clock: &Clock, registry: &Registry) -> Arc<Instance> {
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&REPO),
            &[ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            clock.clone(),
            registry.clone(),
            64,
            5.0,
            ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    }

    struct TestStack {
        gateway: Gateway,
        instances: Vec<Arc<Instance>>,
    }

    impl TestStack {
        fn start(n: usize, cfg: GatewayConfig) -> Self {
            let clock = Clock::real();
            let registry = Registry::new();
            let instances: Vec<Arc<Instance>> = (0..n)
                .map(|i| sim_instance(&format!("gw-{i}"), &clock, &registry))
                .collect();
            let endpoints = Arc::new(RwLock::new(instances.clone()));
            let gateway = Gateway::start(
                &cfg,
                endpoints,
                clock,
                registry,
                Tracer::disabled(),
                None,
            )
            .unwrap();
            TestStack { gateway, instances }
        }

        fn client(&self) -> RpcClient {
            RpcClient::connect(&self.gateway.addr().to_string()).unwrap()
        }
    }

    impl Drop for TestStack {
        fn drop(&mut self) {
            self.gateway.shutdown();
            for i in &self.instances {
                i.stop();
            }
        }
    }

    fn cnn_input(rows: usize) -> Tensor {
        Tensor::zeros(vec![rows, 16, 16, 3])
    }

    #[test]
    fn end_to_end_inference() {
        let stack = TestStack::start(2, GatewayConfig::default());
        let mut client = stack.client();
        let resp = client.infer("icecube_cnn", cnn_input(4)).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.output.shape(), &[4, 3]);
        assert!(resp.compute_us > 0);
    }

    #[test]
    fn health_probe_reflects_endpoints() {
        let stack = TestStack::start(1, GatewayConfig::default());
        let mut client = stack.client();
        assert!(client.health().unwrap());
        stack.instances[0].drain();
        assert!(!client.health().unwrap());
    }

    #[test]
    fn auth_enforced_when_configured() {
        let cfg = GatewayConfig { auth_secret: Some("s3cret".into()), ..Default::default() };
        let stack = TestStack::start(1, cfg);
        let mut anon = stack.client();
        let resp = anon.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Unauthorized);

        let mut authed = stack.client().with_token(&auth::mint_token("s3cret"));
        let resp = authed.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Ok);

        let mut forged = stack.client().with_token("deadbeef");
        let resp = forged.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Unauthorized);
    }

    #[test]
    fn rate_limit_sheds() {
        let cfg = GatewayConfig {
            rate_limit_rps: 5.0,
            rate_limit_burst: 2,
            ..Default::default()
        };
        let stack = TestStack::start(1, cfg);
        let mut client = stack.client();
        let mut limited = 0;
        for _ in 0..10 {
            let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
            if resp.status == Status::RateLimited {
                limited += 1;
            }
        }
        assert!(limited > 0, "no requests rate limited");
    }

    #[test]
    fn unknown_model_not_found() {
        let stack = TestStack::start(1, GatewayConfig::default());
        let mut client = stack.client();
        let resp = client.infer("nope", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::ModelNotFound);
    }

    #[test]
    fn bad_shape_rejected() {
        let stack = TestStack::start(1, GatewayConfig::default());
        let mut client = stack.client();
        let resp = client.infer("icecube_cnn", Tensor::zeros(vec![1, 8, 8, 3])).unwrap();
        assert_eq!(resp.status, Status::BadRequest);
    }

    /// Regression: when the retry pick finds no replica after a first
    /// rejection, the response must say so — the old loop broke out of
    /// the `None` arm without touching `last_msg` and blamed the first
    /// instance's rejection instead of the no-replica condition.
    #[test]
    fn retry_reports_no_ready_not_stale_rejection() {
        let clock = Clock::real();
        let registry = Registry::new();
        // One instance with a 1-row queue and a slow simulated service:
        // the executor is busy and the queue full, so submits reject.
        let inst = Instance::start_with_opts(
            "stale-0",
            Arc::clone(&REPO),
            &[ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(500),
                    per_row: Duration::from_micros(1),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            clock.clone(),
            registry.clone(),
            crate::server::InstanceOptions {
                queue_capacity: 1,
                exec_mode: ExecutionMode::Simulated,
                ..Default::default()
            },
        );
        inst.mark_ready();
        // Occupy the executor, then fill the 1-row queue.
        let _busy = inst.submit("icecube_cnn", cnn_input(1), 0).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        let _queued = inst.submit("icecube_cnn", cnn_input(1), 0).unwrap();
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let gateway = Gateway::start(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Overloaded);
        // Attempt 1 picked the full instance and was rejected; attempt 2
        // (which excludes it) found no other replica — the error must
        // describe the no-replica condition, not just echo attempt 1.
        assert!(
            resp.error.contains("no other ready instance"),
            "stale retry error: '{}'",
            resp.error
        );
        gateway.shutdown();
        inst.stop();
    }

    /// Router-mode twin of the stale-error regression: the retry must
    /// exclude the rejecting replica, and a retry that finds no other
    /// replica must say so rather than echo the first rejection.
    #[test]
    fn router_retry_reports_no_other_replica() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = Instance::start_with_opts(
            "rtr-0",
            Arc::clone(&REPO),
            &[ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(500),
                    per_row: Duration::from_micros(1),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            clock.clone(),
            registry.clone(),
            crate::server::InstanceOptions {
                queue_capacity: 1,
                exec_mode: ExecutionMode::Simulated,
                ..Default::default()
            },
        );
        inst.mark_ready();
        let _busy = inst.submit("icecube_cnn", cnn_input(1), 0).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        let _queued = inst.submit("icecube_cnn", cnn_input(1), 0).unwrap();
        let router = Arc::new(crate::modelmesh::ModelRouter::new(
            &["icecube_cnn".into()],
            crate::config::LbPolicy::RoundRobin,
            0,
            &registry,
            7,
        ));
        router.sync(&[Arc::clone(&inst)]);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let gateway = Gateway::start_with_router(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
            Some(router),
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Overloaded);
        assert!(
            resp.error.contains("no other replica"),
            "stale router retry error: '{}'",
            resp.error
        );
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn tiny_burst_does_not_starve_bulk() {
        // With burst 1 the default bulk_reserve would demand more tokens
        // than the bucket can ever hold; the gateway clamps the reserve
        // so bulk is rate-limited FIRST under contention, never FOREVER.
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("prio-tb", &clock, &registry);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let cfg = GatewayConfig {
            rate_limit_rps: 0.001,
            rate_limit_burst: 1,
            ..Default::default()
        };
        let gateway = Gateway::start_with_priorities(
            &cfg,
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
            None,
            PriorityConfig::default(),
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let r = client
            .infer_prio("icecube_cnn", cnn_input(1), Priority::Bulk)
            .unwrap();
        assert_eq!(r.status, Status::Ok, "bulk starved by an unclamped reserve: {}", r.error);
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn bulk_rate_limited_before_standard() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("prio-rl", &clock, &registry);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let cfg = GatewayConfig {
            // Near-zero refill: the burst is all there is within the test.
            rate_limit_rps: 0.001,
            rate_limit_burst: 4,
            ..Default::default()
        };
        let mut tokens = std::collections::BTreeMap::new();
        tokens.insert("reprocessing".to_string(), Priority::Bulk);
        let priorities = PriorityConfig {
            tokens,
            bulk_reserve: 0.5, // keep 2 of the 4 burst tokens from bulk
            ..Default::default()
        };
        let gateway = Gateway::start_with_priorities(
            &cfg,
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
            None,
            priorities,
        )
        .unwrap();
        let mut bulk =
            RpcClient::connect(&gateway.addr().to_string()).unwrap().with_token("reprocessing");
        let mut standard = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        // Bulk (resolved from its token) may only use the unreserved
        // half of the burst...
        assert_eq!(bulk.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        assert_eq!(bulk.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        assert_eq!(
            bulk.infer("icecube_cnn", cnn_input(1)).unwrap().status,
            Status::RateLimited
        );
        // ...while the reserve still serves the standard client.
        assert_eq!(standard.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        assert_eq!(standard.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn pressure_gate_sheds_by_priority() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("prio-pg", &clock, &registry);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        // Pressure pinned at 1.0 against a 0.6 threshold: over 1x
        // (standard sheds) but under the critical 2x factor.
        let gate = PressureGate::new(Box::new(|| 1.0), 0.6);
        let gateway = Gateway::start_with_priorities(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            Some(gate),
            None,
            PriorityConfig::default(),
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let r = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(r.status, Status::RateLimited, "standard admitted over threshold");
        let r = client
            .infer_prio("icecube_cnn", cnn_input(1), Priority::Bulk)
            .unwrap();
        assert_eq!(r.status, Status::RateLimited, "bulk admitted over threshold");
        let r = client
            .infer_prio("icecube_cnn", cnn_input(1), Priority::Critical)
            .unwrap();
        assert_eq!(r.status, Status::Ok, "critical shed inside its factor: {}", r.error);
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn no_endpoints_overloaded() {
        let cfg = GatewayConfig::default();
        let clock = Clock::real();
        let registry = Registry::new();
        let endpoints = Arc::new(RwLock::new(Vec::new()));
        let gateway = Gateway::start(
            &cfg,
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Overloaded);
        gateway.shutdown();
    }

    #[test]
    fn pressure_gate_sheds() {
        let cfg = GatewayConfig::default();
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("pg-0", &clock, &registry);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let gate = PressureGate::new(Box::new(|| 1.0), 0.5); // always over
        let gateway = Gateway::start(
            &cfg,
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            Some(gate),
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::RateLimited);
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn tracing_records_breakdown() {
        let clock = Clock::real();
        let registry = Registry::new();
        let tracer = Tracer::new(clock.clone(), 1024, true);
        // The instance shares the tracer so queue/batch/compute spans
        // from the server side land on the same trace id.
        let inst = Instance::start_with_opts(
            "tr-0",
            Arc::clone(&REPO),
            &[ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            clock.clone(),
            registry.clone(),
            crate::server::InstanceOptions {
                exec_mode: ExecutionMode::Simulated,
                tracer: tracer.clone(),
                ..Default::default()
            },
        );
        inst.mark_ready();
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let gateway = Gateway::start(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            tracer.clone(),
            None,
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        client.trace_id = tracer.new_trace();
        let resp = client.infer("icecube_cnn", cnn_input(2)).unwrap();
        assert_eq!(resp.status, Status::Ok);
        let view = tracer.trace(client.trace_id);
        let names: Vec<&str> = view.spans.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"gateway"), "{names:?}");
        assert!(names.contains(&"admit"), "{names:?}");
        assert!(names.contains(&"ratelimit"), "{names:?}");
        assert!(names.contains(&"route"), "{names:?}");
        assert!(names.contains(&"queue"), "{names:?}");
        assert!(names.contains(&"compute"), "{names:?}");
        assert!(view.duration_of("compute") > 0.0);
        gateway.shutdown();
        inst.stop();
    }

    /// The wire sampling bit must be honored server-side: a request that
    /// carries a trace id but was head-sampled *out* leaves no spans.
    #[test]
    fn sampled_out_request_leaves_no_spans() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("tr-1", &clock, &registry);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&inst)]));
        let tracer = Tracer::new(clock.clone(), 1024, true);
        let gateway = Gateway::start(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            tracer.clone(),
            None,
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string())
            .unwrap()
            .with_trace(tracer.new_trace(), false);
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert!(tracer.trace(client.trace_id).spans.is_empty());
        assert!(tracer.is_empty());
        gateway.shutdown();
        inst.stop();
    }

    #[test]
    fn connection_limit_refuses_excess() {
        let cfg = GatewayConfig { max_connections: 2, ..GatewayConfig::default() };
        let stack = TestStack::start(1, cfg);
        // Two connections work; keep them open with a request each.
        let mut c1 = stack.client();
        let mut c2 = stack.client();
        assert_eq!(c1.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        assert_eq!(c2.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
        // A third is accepted at TCP level then closed by the listener:
        // its first request fails.
        std::thread::sleep(Duration::from_millis(50));
        let mut c3 = RpcClient::connect(&stack.gateway.addr().to_string()).unwrap();
        assert!(c3.infer("icecube_cnn", cnn_input(1)).is_err());
        // Closing one earlier connection frees a slot.
        drop(c1);
        std::thread::sleep(Duration::from_millis(300));
        let mut c4 = stack.client();
        assert_eq!(c4.infer("icecube_cnn", cnn_input(1)).unwrap().status, Status::Ok);
    }

    #[test]
    fn model_router_routes_by_model() {
        let clock = Clock::real();
        let registry = Registry::new();
        let repo = Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into(), "particlenet".into()],
            )
            .unwrap(),
        );
        let models: Vec<ModelConfig> = ["icecube_cnn", "particlenet"]
            .iter()
            .map(|m| ModelConfig {
                name: m.to_string(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            })
            .collect();
        let mk = |id: &str| {
            let inst = Instance::start_with_mode(
                id,
                Arc::clone(&repo),
                &models,
                clock.clone(),
                registry.clone(),
                64,
                5.0,
                ExecutionMode::Simulated,
            );
            inst.mark_ready();
            inst
        };
        let a = mk("mesh-a");
        let b = mk("mesh-b");
        // disjoint serving sets: a=cnn only, b=particlenet only
        a.set_loaded_models(&["icecube_cnn".into()]);
        b.set_loaded_models(&["particlenet".into()]);
        let router = Arc::new(crate::modelmesh::ModelRouter::new(
            &["icecube_cnn".into(), "particlenet".into()],
            crate::config::LbPolicy::RoundRobin,
            0,
            &registry,
            3,
        ));
        router.sync(&[Arc::clone(&a), Arc::clone(&b)]);
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(&a), Arc::clone(&b)]));
        let gateway = Gateway::start_with_router(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
            Some(Arc::clone(&router)),
        )
        .unwrap();
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();

        // Each model lands on the instance advertising it (output widths
        // differ per model, proving the right engine family served it).
        let r1 = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(r1.status, Status::Ok, "{}", r1.error);
        assert_eq!(r1.output.shape(), &[1, 3]);
        let r2 = client.infer("particlenet", Tensor::zeros(vec![1, 64, 7])).unwrap();
        assert_eq!(r2.status, Status::Ok, "{}", r2.error);
        assert_eq!(r2.output.shape(), &[1, 2]);

        // Outside the catalog: not found.
        let r3 = client.infer("nope", Tensor::zeros(vec![1, 2])).unwrap();
        assert_eq!(r3.status, Status::ModelNotFound);

        // Unloading the only replica sheds that model, others unaffected.
        assert!(router.unload(&b, "particlenet"));
        let r4 = client.infer("particlenet", Tensor::zeros(vec![1, 64, 7])).unwrap();
        assert_eq!(r4.status, Status::Overloaded);
        let r5 = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(r5.status, Status::Ok);

        assert_eq!(router.routed_count("icecube_cnn"), 2);
        gateway.shutdown();
        a.stop();
        b.stop();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let stack = TestStack::start(3, GatewayConfig::default());
        let addr = stack.gateway.addr().to_string();
        let mut handles = Vec::new();
        for _ in 0..6 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                let mut ok = 0;
                for _ in 0..5 {
                    let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
                    if resp.status == Status::Ok {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 30, "all requests served");
    }

    fn remote_rpc_cfg() -> RpcConfig {
        RpcConfig {
            remote_dispatch: true,
            dispatch_threads: 4,
            pool_size: 2,
            io_timeout: Duration::from_millis(500),
            ..Default::default()
        }
    }

    fn start_remote_gateway(
        inst: &Arc<Instance>,
        clock: Clock,
        registry: Registry,
        rpc: &RpcConfig,
    ) -> Gateway {
        let endpoints = Arc::new(RwLock::new(vec![Arc::clone(inst)]));
        Gateway::start_full(
            &GatewayConfig::default(),
            endpoints,
            clock,
            registry,
            Tracer::disabled(),
            None,
            None,
            PriorityConfig::default(),
            rpc,
        )
        .unwrap()
    }

    #[test]
    fn remote_dispatch_serves_over_pooled_sessions() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("rd-0", &clock, &registry);
        inst.serve_rpc(
            "127.0.0.1:0",
            crate::rpc::RpcServerOpts { workers: 2, dispatch_threads: 4, ..Default::default() },
        )
        .unwrap();
        let gateway = start_remote_gateway(&inst, clock, registry, &remote_rpc_cfg());
        // RpcClient verifies response ids against request ids, so these
        // calls also prove the gateway rewrites the backend session's
        // wire id back to the client's id.
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        for rows in [1usize, 4, 2] {
            let resp = client.infer("icecube_cnn", cnn_input(rows)).unwrap();
            assert_eq!(resp.status, Status::Ok, "{}", resp.error);
            assert_eq!(resp.output.shape(), &[rows, 3]);
            assert!(resp.compute_us > 0, "latency breakdown lost on the wire");
        }
        let pool = gateway.session_pool().expect("remote dispatch pools sessions");
        let backend = inst.rpc_addr().unwrap();
        assert_eq!(pool.connects(), 1, "hops must reuse the warm session");
        assert_eq!(pool.open_sessions(&backend), 1);
        gateway.shutdown();
        inst.stop();
    }

    /// Regression for the hung-backend hazard: a backend that accepts the
    /// connection but never answers must cost one io timeout and come
    /// back retryable (`Overloaded`), not block the gateway forever.
    #[test]
    fn hung_remote_backend_times_out_as_overloaded() {
        let clock = Clock::real();
        let registry = Registry::new();
        let inst = sim_instance("rd-hung", &clock, &registry);
        let silent = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let silent_addr = silent.local_addr().unwrap().to_string();
        let _keeper = std::thread::spawn(move || silent.accept().map(|(s, _)| s));
        inst.set_rpc_addr_for_test(&silent_addr);
        let rpc = RpcConfig { io_timeout: Duration::from_millis(200), ..remote_rpc_cfg() };
        let gateway = start_remote_gateway(&inst, clock, registry, &rpc);
        let mut client = RpcClient::connect(&gateway.addr().to_string()).unwrap();
        let t0 = std::time::Instant::now();
        let resp = client.infer("icecube_cnn", cnn_input(1)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "io timeout never fired");
        assert_eq!(resp.status, Status::Overloaded, "{}", resp.error);
        gateway.shutdown();
        inst.stop();
    }
}
