//! Session pool: warm multiplexed connections per backend address.
//!
//! When the gateway forwards routed requests over the network
//! (`rpc.remote_dispatch`), dialing a fresh TCP connection per hop would
//! dominate the request latency. The pool keeps up to `rpc.pool_size`
//! warm [`RpcSession`]s per backend address; a routed hop checks one out
//! (really: borrows a shared `Arc` — sessions are multiplexed, so many
//! hops ride one session concurrently), pipelines its request, and the
//! session's demultiplexing reader matches the response back by id.
//!
//! Checkout picks the least-loaded open session under the per-connection
//! in-flight bound; when every session is saturated and the pool is at
//! size, the hop is refused (`rpc_pool_exhausted_total`) and the gateway
//! sheds the request as retryable `Overloaded` — the same backpressure
//! story as the in-process submit path.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::config::RpcConfig;
use crate::metrics::registry::{labels, Counter, Registry};
use crate::rpc::session::{RpcSession, SessionOpts};

/// Warm [`RpcSession`]s keyed by backend address.
pub struct SessionPool {
    cfg: RpcConfig,
    sessions: Mutex<HashMap<String, Vec<Arc<RpcSession>>>>,
    m_connects: Counter,
    m_exhausted: Counter,
    m_transport_errors: Counter,
}

impl SessionPool {
    pub fn new(cfg: RpcConfig, registry: &Registry) -> Self {
        SessionPool {
            cfg,
            sessions: Mutex::new(HashMap::new()),
            m_connects: registry.counter("rpc_pool_connects_total", &labels(&[])),
            m_exhausted: registry.counter("rpc_pool_exhausted_total", &labels(&[])),
            m_transport_errors: registry.counter("rpc_transport_errors_total", &labels(&[])),
        }
    }

    /// Borrow a session to `addr`: the least-loaded open session with
    /// in-flight headroom, dialing a new one while the pool is under
    /// `pool_size`. Fails when the pool is saturated (every session at
    /// the in-flight bound) or the dial itself fails.
    pub fn checkout(&self, addr: &str) -> Result<Arc<RpcSession>> {
        let mut sessions = self.sessions.lock().unwrap();
        let pool = sessions.entry(addr.to_string()).or_default();
        // Drop sessions whose transport died; their waiters were already
        // failed by the session's own poison path.
        pool.retain(|s| !s.is_closed());

        let cap = self.cfg.max_inflight_per_conn;
        let best = pool
            .iter()
            .filter(|s| cap == 0 || s.in_flight() < cap)
            .min_by_key(|s| s.in_flight())
            .cloned();
        if let Some(session) = best {
            return Ok(session);
        }
        if pool.len() < self.cfg.pool_size {
            let session = Arc::new(RpcSession::connect(
                addr,
                SessionOpts {
                    connect_timeout: Some(self.cfg.io_timeout),
                    io_timeout: Some(self.cfg.io_timeout),
                },
            )?);
            self.m_connects.inc();
            pool.push(Arc::clone(&session));
            return Ok(session);
        }
        self.m_exhausted.inc();
        bail!(
            "session pool to {addr} exhausted: {} sessions all at the \
             in-flight bound ({cap})",
            pool.len()
        );
    }

    /// Drop closed sessions for `addr` (called after a hop sees its
    /// session die, so the next checkout redials instead of re-picking
    /// the corpse).
    pub fn evict_closed(&self, addr: &str) {
        if let Some(pool) = self.sessions.lock().unwrap().get_mut(addr) {
            pool.retain(|s| !s.is_closed());
        }
    }

    /// Count a failed hop against `rpc_transport_errors_total`.
    pub fn note_transport_error(&self) {
        self.m_transport_errors.inc();
    }

    /// Open (non-closed) sessions currently pooled for `addr`.
    pub fn open_sessions(&self, addr: &str) -> usize {
        self.sessions
            .lock()
            .unwrap()
            .get(addr)
            .map(|p| p.iter().filter(|s| !s.is_closed()).count())
            .unwrap_or(0)
    }

    /// Total dials performed over the pool's lifetime.
    pub fn connects(&self) -> u64 {
        self.m_connects.get()
    }

    /// Checkouts refused because every session was saturated.
    pub fn exhausted(&self) -> u64 {
        self.m_exhausted.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::codec::{InferRequest, InferResponse, RequestKind};
    use crate::rpc::server::{Handler, RpcServer, RpcServerOpts};
    use crate::runtime::Tensor;
    use std::time::Duration;

    fn echo_server() -> RpcServer {
        let handler: Handler = Arc::new(|req: InferRequest| match req.kind {
            RequestKind::Health => InferResponse::ok(req.request_id, Tensor::zeros(vec![0])),
            RequestKind::Infer => InferResponse::ok(req.request_id, req.input),
        });
        RpcServer::start_with_opts(
            "127.0.0.1:0",
            RpcServerOpts { workers: 2, dispatch_threads: 4, ..Default::default() },
            handler,
        )
        .unwrap()
    }

    fn pool_cfg(pool_size: usize, inflight: usize) -> RpcConfig {
        RpcConfig {
            pool_size,
            max_inflight_per_conn: inflight,
            io_timeout: Duration::from_secs(2),
            ..Default::default()
        }
    }

    #[test]
    fn checkout_reuses_warm_session() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let pool = SessionPool::new(pool_cfg(4, 0), &Registry::new());
        let a = pool.checkout(&addr).unwrap();
        a.infer("m", Tensor::zeros(vec![1])).unwrap();
        let b = pool.checkout(&addr).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "idle session not reused");
        assert_eq!(pool.connects(), 1, "reuse must not redial");
    }

    #[test]
    fn saturated_pool_reports_exhaustion() {
        let server = echo_server();
        let addr = server.addr().to_string();
        // pool_size 1, in-flight cap 1: a silent backend (accepts, never
        // answers) keeps the one slot occupied so the next checkout must
        // report exhaustion instead of over-subscribing the session.
        let silent = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let silent_addr = silent.local_addr().unwrap().to_string();
        let keeper = std::thread::spawn(move || silent.accept().map(|(s, _)| s));
        let pool = SessionPool::new(pool_cfg(1, 1), &Registry::new());
        let s = pool.checkout(&silent_addr).unwrap();
        let req = InferRequest::infer(0, "m", Tensor::zeros(vec![1]));
        let _pending = s.submit(&req).unwrap(); // occupies the only slot
        let err = pool.checkout(&silent_addr).unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"), "got: {err:#}");
        assert_eq!(pool.exhausted(), 1);
        // A different backend is unaffected.
        assert!(pool.checkout(&addr).is_ok());
        drop(keeper);
    }

    #[test]
    fn closed_sessions_are_evicted_and_redialed() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let pool = SessionPool::new(pool_cfg(2, 0), &Registry::new());
        let s = pool.checkout(&addr).unwrap();
        s.shutdown();
        assert!(s.is_closed());
        pool.evict_closed(&addr);
        assert_eq!(pool.open_sessions(&addr), 0);
        let s2 = pool.checkout(&addr).unwrap();
        assert!(!Arc::ptr_eq(&s, &s2));
        s2.infer("m", Tensor::zeros(vec![1])).unwrap();
        assert_eq!(pool.connects(), 2);
    }
}
