//! Token-based authentication (§2.2: "Token-based authentication secures
//! client endpoints, preventing unauthorized access").
//!
//! Tokens are HMAC-SHA256 tags over a fixed context string under the
//! deployment's shared secret, hex-encoded. Verification recomputes the
//! tag and compares in constant time (`subtle`), so the check leaks no
//! timing information about how much of a forged token matched.

use hmac::{Hmac, Mac};
use sha2::Sha256;
use subtle::ConstantTimeEq;

type HmacSha256 = Hmac<Sha256>;

/// Domain-separation context baked into every token.
const TOKEN_CONTEXT: &[u8] = b"supersonic-client-token-v1";

/// Mint the client token for a deployment secret.
pub fn mint_token(secret: &str) -> String {
    let mut mac = HmacSha256::new_from_slice(secret.as_bytes())
        .expect("HMAC accepts any key length");
    mac.update(TOKEN_CONTEXT);
    hex_encode(&mac.finalize().into_bytes())
}

/// Verify a presented token against the deployment secret.
pub fn verify_token(secret: &str, token: &str) -> bool {
    let expected = mint_token(secret);
    // Length comparison is not secret; content comparison is.
    if expected.len() != token.len() {
        return false;
    }
    expected.as_bytes().ct_eq(token.as_bytes()).into()
}

fn hex_encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = Vec::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize]);
        s.push(HEX[(b & 0xf) as usize]);
    }
    // SAFETY-free: HEX is pure ASCII.
    String::from_utf8(s).expect("hex is ascii")
}

/// Authenticator attached to the gateway: `None` secret = auth disabled.
pub struct Authenticator {
    secret: Option<String>,
}

impl Authenticator {
    /// Build from the gateway config's optional secret.
    pub fn new(secret: Option<String>) -> Self {
        Authenticator { secret }
    }

    /// True when auth is enforced.
    pub fn enabled(&self) -> bool {
        self.secret.is_some()
    }

    /// Check a request token.
    pub fn check(&self, token: &str) -> bool {
        match &self.secret {
            None => true,
            Some(secret) => verify_token(secret, token),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip() {
        let t = mint_token("hunter2");
        assert!(verify_token("hunter2", &t));
    }

    #[test]
    fn wrong_secret_rejected() {
        let t = mint_token("hunter2");
        assert!(!verify_token("hunter3", &t));
    }

    #[test]
    fn garbage_token_rejected() {
        assert!(!verify_token("hunter2", ""));
        assert!(!verify_token("hunter2", "deadbeef"));
        let mut t = mint_token("hunter2");
        t.replace_range(0..1, if t.starts_with('0') { "1" } else { "0" });
        assert!(!verify_token("hunter2", &t));
    }

    #[test]
    fn tokens_deterministic_per_secret() {
        assert_eq!(mint_token("a"), mint_token("a"));
        assert_ne!(mint_token("a"), mint_token("b"));
    }

    #[test]
    fn disabled_auth_accepts_anything() {
        let a = Authenticator::new(None);
        assert!(!a.enabled());
        assert!(a.check(""));
        assert!(a.check("whatever"));
    }

    #[test]
    fn enabled_auth_enforces() {
        let a = Authenticator::new(Some("s3cret".into()));
        assert!(a.enabled());
        assert!(a.check(&mint_token("s3cret")));
        assert!(!a.check("nope"));
        assert!(!a.check(""));
    }
}
