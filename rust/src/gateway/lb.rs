//! Load balancing across Triton instances (§2.2: "Load balancing
//! distributes incoming requests across multiple Triton instances using
//! predefined algorithms such as round robin").
//!
//! The balancer sees the live endpoint list maintained by the cluster
//! reconcile loop (only `Ready` instances appear there) and additionally
//! enforces the per-instance in-flight cap — Envoy's circuit-breaking-style
//! overload protection — before handing a request to an instance.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::LbPolicy;
use crate::server::{Instance, InstanceState};
use crate::util::rng::Rng;

/// Policy-driven endpoint picker.
pub struct LoadBalancer {
    policy: LbPolicy,
    endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
    rr_cursor: AtomicUsize,
    rng: Mutex<Rng>,
    /// Per-instance outstanding-request cap (0 = uncapped).
    max_inflight: usize,
}

impl LoadBalancer {
    /// Balancer over a shared endpoint list.
    pub fn new(
        policy: LbPolicy,
        endpoints: Arc<RwLock<Vec<Arc<Instance>>>>,
        max_inflight: usize,
        seed: u64,
    ) -> Self {
        LoadBalancer {
            policy,
            endpoints,
            rr_cursor: AtomicUsize::new(0),
            rng: Mutex::new(Rng::seeded(seed)),
            max_inflight,
        }
    }

    /// Configured policy.
    pub fn policy(&self) -> LbPolicy {
        self.policy
    }

    /// Number of currently routable endpoints.
    pub fn healthy_count(&self) -> usize {
        self.endpoints
            .read()
            .unwrap()
            .iter()
            .filter(|i| i.state() == InstanceState::Ready)
            .count()
    }

    /// Pick an instance for the next request, or `None` when every
    /// endpoint is gone or saturated (the caller sheds the request).
    pub fn pick(&self) -> Option<Arc<Instance>> {
        self.pick_excluding(None)
    }

    /// [`LoadBalancer::pick`] skipping the instance named `exclude` —
    /// the gateway's retry path, which must land on a *different*
    /// instance than the one that just rejected the request.
    pub fn pick_excluding(&self, exclude: Option<&str>) -> Option<Arc<Instance>> {
        let eps = self.endpoints.read().unwrap();
        let routable = |i: &Arc<Instance>| {
            i.state() == InstanceState::Ready
                && (self.max_inflight == 0 || i.inflight() < self.max_inflight)
                && exclude.is_none_or(|id| i.id != id)
        };

        // Round-robin rotates over the *full* endpoint list, skipping
        // ineligible entries without consuming a cursor slot for them.
        // The previous implementation advanced the cursor over a
        // re-filtered eligible list, so a saturated/draining endpoint
        // shifted which instance subsequent picks landed on and starved
        // the endpoints after it; anchoring the rotation on stable list
        // positions keeps the cycle fair across eligibility changes.
        if self.policy == LbPolicy::RoundRobin {
            let len = eps.len();
            if len == 0 {
                return None;
            }
            loop {
                let cur = self.rr_cursor.load(Ordering::Relaxed);
                let start = cur % len;
                let hit = (0..len)
                    .map(|off| (start + off) % len)
                    .find(|&i| routable(&eps[i]));
                let Some(i) = hit else { return None };
                if self
                    .rr_cursor
                    .compare_exchange_weak(
                        cur,
                        (i + 1) % len,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    return Some(Arc::clone(&eps[i]));
                }
            }
        }

        let eligible: Vec<&Arc<Instance>> = eps.iter().filter(|i| routable(i)).collect();
        if eligible.is_empty() {
            return None;
        }
        let chosen = match self.policy {
            // Handled above (needs full-list positions, not the filtered
            // view).
            LbPolicy::RoundRobin => unreachable!("round-robin picked early"),
            LbPolicy::Random => {
                let idx = self.rng.lock().unwrap().below(eligible.len());
                eligible[idx]
            }
            // Envoy's least-request: power-of-two-choices. A deterministic
            // global minimum would break ties by list position and funnel
            // all idle-pool traffic onto the first instances (observed on
            // the 100-server bench: 28/100 instances served); sampling two
            // random candidates spreads ties uniformly while still routing
            // around loaded instances.
            LbPolicy::LeastConnection => {
                let mut rng = self.rng.lock().unwrap();
                let a = rng.below(eligible.len());
                let b = rng.below(eligible.len());
                drop(rng);
                if eligible[a].inflight() <= eligible[b].inflight() {
                    eligible[a]
                } else {
                    eligible[b]
                }
            }
            // Same two-choice sampling on the utilization signal.
            LbPolicy::UtilizationAware => {
                let mut rng = self.rng.lock().unwrap();
                let a = rng.below(eligible.len());
                let b = rng.below(eligible.len());
                drop(rng);
                if eligible[a].utilization() <= eligible[b].utilization() {
                    eligible[a]
                } else {
                    eligible[b]
                }
            }
        };
        Some(Arc::clone(chosen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::metrics::Registry;
    use crate::server::ModelRepository;
    use crate::util::clock::Clock;
    use once_cell::sync::Lazy;

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn instance(id: &str) -> Arc<Instance> {
        let inst = Instance::start_with_mode(
            id,
            Arc::clone(&REPO),
            &[ModelConfig { name: "icecube_cnn".into(), ..ModelConfig::default() }],
            Clock::real(),
            Registry::new(),
            64,
            5.0,
            crate::config::ExecutionMode::Simulated,
        );
        inst.mark_ready();
        inst
    }

    fn endpoints(n: usize) -> (Arc<RwLock<Vec<Arc<Instance>>>>, Vec<Arc<Instance>>) {
        let insts: Vec<Arc<Instance>> = (0..n).map(|i| instance(&format!("lb-{i}"))).collect();
        (Arc::new(RwLock::new(insts.clone())), insts)
    }

    #[test]
    fn round_robin_cycles() {
        let (eps, insts) = endpoints(3);
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 0, 1);
        let picks: Vec<String> = (0..6).map(|_| lb.pick().unwrap().id.clone()).collect();
        assert_eq!(picks[0..3], picks[3..6]);
        let mut uniq = picks[0..3].to_vec();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "all three instances used: {picks:?}");
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn empty_endpoints_returns_none() {
        let eps = Arc::new(RwLock::new(Vec::new()));
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 0, 1);
        assert!(lb.pick().is_none());
        assert_eq!(lb.healthy_count(), 0);
    }

    #[test]
    fn non_ready_instances_skipped() {
        let (eps, insts) = endpoints(2);
        insts[0].drain();
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 0, 1);
        for _ in 0..4 {
            assert_eq!(lb.pick().unwrap().id, insts[1].id);
        }
        assert_eq!(lb.healthy_count(), 1);
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn least_connection_prefers_idle() {
        let (eps, insts) = endpoints(2);
        // Occupy instance 0 with queued work (simulated batches sleep).
        let _rx = insts[0]
            .submit("icecube_cnn", crate::runtime::Tensor::zeros(vec![1, 16, 16, 3]), 0)
            .unwrap();
        let lb = LoadBalancer::new(LbPolicy::LeastConnection, eps, 0, 1);
        // Power-of-two-choices: when both candidates differ the idle
        // instance wins; with 2 endpoints the busy one is picked only
        // when both samples land on it (~1/4), so a clear majority of
        // picks must go to the idle instance.
        let idle_picks = (0..40)
            .filter(|_| lb.pick().unwrap().id == insts[1].id)
            .count();
        assert!(idle_picks >= 25, "only {idle_picks}/40 picks went to the idle instance");
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn least_connection_spreads_ties() {
        // All-idle pool: two-choice sampling must not funnel traffic onto
        // the first instance (the 100-server fairness regression).
        let (eps, insts) = endpoints(3);
        let lb = LoadBalancer::new(LbPolicy::LeastConnection, eps, 0, 7);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(lb.pick().unwrap().id.clone());
        }
        assert_eq!(seen.len(), 3, "ties not spread: {seen:?}");
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn round_robin_skips_saturated_without_starving() {
        // Endpoint 1 is saturated (cap 1, one queued request). The
        // rotation must keep alternating 0, 2, 0, 2 — the saturated
        // endpoint is skipped without shifting the cycle, so endpoint 2
        // (after the saturated one) is not starved.
        let (eps, insts) = endpoints(3);
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 1, 1);
        let _rx = insts[1]
            .submit("icecube_cnn", crate::runtime::Tensor::zeros(vec![1, 16, 16, 3]), 0)
            .unwrap();
        let picks: Vec<String> = (0..6).map(|_| lb.pick().unwrap().id.clone()).collect();
        let ones = picks.iter().filter(|id| **id == insts[1].id).count();
        assert_eq!(ones, 0, "picked a saturated endpoint: {picks:?}");
        let zeros = picks.iter().filter(|id| **id == insts[0].id).count();
        let twos = picks.iter().filter(|id| **id == insts[2].id).count();
        assert_eq!(zeros, 3, "endpoint 0 starved: {picks:?}");
        assert_eq!(twos, 3, "endpoint 2 starved after the saturated one: {picks:?}");
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn round_robin_resumes_recovered_endpoint() {
        // Drain endpoint 0, take two picks, recover it: the rotation
        // continues from its position instead of jumping.
        let (eps, insts) = endpoints(3);
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 0, 1);
        insts[0].drain();
        assert_eq!(lb.pick().unwrap().id, insts[1].id);
        assert_eq!(lb.pick().unwrap().id, insts[2].id);
        insts[0].mark_ready();
        assert_eq!(lb.pick().unwrap().id, insts[0].id);
        assert_eq!(lb.pick().unwrap().id, insts[1].id);
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn inflight_cap_saturates_to_none() {
        let (eps, insts) = endpoints(1);
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 1, 1);
        assert!(lb.pick().is_some());
        let _rx = insts[0]
            .submit("icecube_cnn", crate::runtime::Tensor::zeros(vec![1, 16, 16, 3]), 0)
            .unwrap();
        // inflight == cap => shed
        assert!(lb.pick().is_none());
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn pick_excluding_skips_named_instance() {
        let (eps, insts) = endpoints(2);
        let lb = LoadBalancer::new(LbPolicy::RoundRobin, eps, 0, 1);
        for _ in 0..4 {
            let picked = lb.pick_excluding(Some(insts[0].id.as_str())).unwrap();
            assert_eq!(picked.id, insts[1].id);
        }
        // excluding the only remaining instance sheds
        insts[1].drain();
        assert!(lb.pick_excluding(Some(insts[0].id.as_str())).is_none());
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn random_policy_covers_all() {
        let (eps, insts) = endpoints(3);
        let lb = LoadBalancer::new(LbPolicy::Random, eps, 0, 42);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..100 {
            seen.insert(lb.pick().unwrap().id.clone());
        }
        assert_eq!(seen.len(), 3);
        for i in insts {
            i.stop();
        }
    }

    #[test]
    fn utilization_aware_runs() {
        let (eps, insts) = endpoints(2);
        let lb = LoadBalancer::new(LbPolicy::UtilizationAware, eps, 0, 1);
        assert!(lb.pick().is_some());
        for i in insts {
            i.stop();
        }
    }
}
