//! Deployment bundle — the Helm release analogue.
//!
//! "To streamline installation and version control, [SuperSONIC] is
//! distributed as a Helm chart" (§2). [`Deployment::up`] is `helm
//! install`: it takes one validated [`DeploymentConfig`] and boots every
//! component in dependency order —
//!
//! 1. clock (with the experiment's time dilation),
//! 2. metrics registry + time-series store + scraper (§2.3),
//! 3. tracer (§2.3),
//! 4. model repository (compiled through PJRT, or metadata-only for
//!    simulated execution),
//! 5. cluster simulator + instance factory (§2),
//! 6. gateway (§2.2) over the cluster's live endpoint list,
//! 7. autoscaler (§2.4) driving the cluster's desired replicas,
//! 8. optional `/metrics` HTTP endpoint.
//!
//! [`Deployment::down`] tears everything back down in reverse order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::autoscaler::{Autoscaler, CpuScaler, CpuShareProbe, DemandProbe, PerModelScaler};
use crate::config::{
    ClusterConfig, DeploymentConfig, ExecutionMode, ModelConfig, PerModelScalingConfig,
};
use crate::engine::{AcceleratorClass, BackendRegistry, EngineCatalog};
use crate::federation::{Federation, FederationRouter, Rebalancer, Site};
use crate::gateway::ratelimit::PressureGate;
use crate::gateway::Gateway;
use crate::metrics::exposition::{DebugProvider, MetricsServer};
use crate::metrics::{MetricStore, Registry, Scraper};
use crate::modelmesh::{initial_placement, ModelRouter, PlacementController, RampTask};
use crate::orchestrator::{Cluster, InstanceFactory};
use crate::runtime::PjrtRuntime;
use crate::server::{split_version, versioned_name, Instance, ModelRepository};
use crate::telemetry::flight::{ExplainFilter, FlightRecorder};
use crate::telemetry::rollback::{
    CanaryProbe, CanarySnapshot, RollbackAction, RollbackEngine, RollbackTask,
};
use crate::telemetry::slo::{SloEngine, SloTask};
use crate::telemetry::Tracer;
use crate::util::clock::Clock;

/// A running SuperSONIC deployment.
pub struct Deployment {
    pub cfg: DeploymentConfig,
    pub clock: Clock,
    pub registry: Registry,
    pub store: MetricStore,
    pub tracer: Tracer,
    pub repository: Arc<ModelRepository>,
    pub cluster: Arc<Cluster>,
    pub gateway: Gateway,
    pub autoscaler: Arc<Autoscaler>,
    /// Per-model autoscaler, when `autoscaler.per_model` is enabled (the
    /// global [`Autoscaler`] loop is inert in that case).
    pub per_model_scaler: Option<Arc<PerModelScaler>>,
    /// Model-aware routing table, when the modelmesh is active.
    pub router: Option<Arc<ModelRouter>>,
    /// Placement controller, when the modelmesh is active.
    pub placement: Option<Arc<PlacementController>>,
    /// SLO burn-rate engine, when `observability.slos` is non-empty.
    pub slo: Option<Arc<SloEngine>>,
    /// Canary auto-rollback evaluator, when any model configures a
    /// `canary` split.
    pub rollback: Option<Arc<RollbackEngine>>,
    /// Multi-site federation control plane, when `federation.sites` is
    /// non-empty. The single-cluster fields above then describe the
    /// gateway site's slice of the deployment (`cluster`, `router` and
    /// `placement` are that site's); the other sites live here.
    pub federation: Option<Arc<Federation>>,
    /// Class-partitioned CPU autoscaler, when `engines.cpu_max_replicas`
    /// lifts the CPU group's ceiling above its floor.
    pub cpu_scaler: Option<Arc<CpuScaler>>,
    /// Control-plane flight recorder, when
    /// `observability.flight_recorder_capacity` is non-zero. Every
    /// control loop's decisions land here; query with
    /// [`FlightRecorder::explain`] or `supersonic explain`.
    pub flight: Option<Arc<FlightRecorder>>,
    /// Staged canary ramp loops (one per model with `canary.ramp`).
    ramp_tasks: Vec<RampTask>,
    metrics_http: Option<MetricsServer>,
    _slo_task: Option<SloTask>,
    _rollback_task: Option<RollbackTask>,
    _scraper: Scraper,
}

/// Initial per-model pod targets: `initial` pods spread round-robin over
/// the catalog, each model clamped into its configured bounds (floors
/// win over the round-robin share, so the sum may exceed `initial`).
fn initial_model_targets(
    initial: usize,
    models: &[String],
    pm: &PerModelScalingConfig,
) -> BTreeMap<String, usize> {
    let n = models.len().max(1);
    models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let share = initial / n + usize::from(i < initial % n);
            (m.clone(), share.clamp(pm.min_replicas, pm.max_replicas))
        })
        .collect()
}

impl Deployment {
    /// Boot a deployment (`helm install`).
    pub fn up(cfg: DeploymentConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.federation.enabled() {
            return Self::up_federated(cfg);
        }
        let clock = if (cfg.time_scale - 1.0).abs() < f64::EPSILON {
            Clock::real()
        } else {
            Clock::scaled(cfg.time_scale)
        };
        let registry = Registry::new();
        let store = MetricStore::new(cfg.monitoring.retention);
        let scraper = Scraper::start(
            registry.clone(),
            store.clone(),
            clock.clone(),
            cfg.monitoring.scrape_interval,
        );
        let tracer = if cfg.monitoring.tracing {
            Tracer::new(clock.clone(), cfg.observability.trace_capacity, true)
                .with_sample_rate(cfg.observability.trace_sample_rate)
        } else {
            Tracer::disabled()
        };
        // Export drop accounting even when tracing is off: a flat-zero
        // `trace_spans_dropped_total` is the healthy-baseline signal.
        tracer.bind_registry(&registry);

        // Control-plane flight recorder: one bounded ring every control
        // loop reports its decisions into (installed below, once the
        // loops exist).
        let flight = (cfg.observability.flight_recorder_capacity > 0).then(|| {
            Arc::new(FlightRecorder::new(
                clock.clone(),
                cfg.observability.flight_recorder_capacity,
                cfg.observability.explain_horizon.as_secs_f64(),
                registry.clone(),
            ))
        });

        // Model repository: compile through PJRT only when instances will
        // actually execute.
        let model_names: Vec<String> =
            cfg.server.models.iter().map(|m| m.name.clone()).collect();
        let repository = Arc::new(match cfg.server.execution {
            ExecutionMode::Real => {
                let runtime = PjrtRuntime::cpu().context("creating PJRT client")?;
                ModelRepository::load(&runtime, &cfg.server.repository, &model_names)?
            }
            ExecutionMode::Simulated => {
                ModelRepository::load_metadata(&cfg.server.repository, &model_names)?
            }
        });

        // Versioned rollouts: each configured `versions:` entry becomes
        // its own servable config `base@vN` sharing the base weights (the
        // repository registers the same entry under the versioned key),
        // and the incumbent version is recorded so boot profiles resolve
        // to it. A version's `slowdown` scales the simulated service
        // model — how experiments ship a deliberately slower canary.
        let mut serving_models: Vec<ModelConfig> = Vec::new();
        for m in &cfg.server.models {
            if m.versions.is_empty() {
                serving_models.push(m.clone());
                continue;
            }
            for spec in &m.versions {
                repository.register_version(&m.name, spec.version)?;
                let mut vm = m.clone();
                vm.name = versioned_name(&m.name, spec.version);
                vm.versions = Vec::new();
                vm.incumbent = None;
                vm.canary = None;
                vm.pinned_version = None;
                if (spec.slowdown - 1.0).abs() > f64::EPSILON {
                    let scale = |d: Duration| Duration::from_secs_f64(d.as_secs_f64() * spec.slowdown);
                    vm.service_model.base = scale(vm.service_model.base);
                    vm.service_model.per_row = scale(vm.service_model.per_row);
                }
                serving_models.push(vm);
            }
            if let Some(v) = m.incumbent_version() {
                repository.set_incumbent(&m.name, v);
            }
        }
        let serving_names: Vec<String> =
            serving_models.iter().map(|m| m.name.clone()).collect();
        // The versions a rollout actively serves (incumbent + canary +
        // pin); other listed versions stay registered but boot cold.
        let active_serving: std::collections::BTreeSet<String> = cfg
            .server
            .models
            .iter()
            .flat_map(|m| {
                if m.versions.is_empty() {
                    return vec![m.name.clone()];
                }
                let mut active: Vec<String> = Vec::new();
                if let Some(v) = m.incumbent_version() {
                    active.push(versioned_name(&m.name, v));
                }
                if let Some(c) = &m.canary {
                    active.push(versioned_name(&m.name, c.version));
                }
                if let Some(p) = m.pinned_version {
                    active.push(versioned_name(&m.name, p));
                }
                active
            })
            .collect();

        // Multi-backend engine layer: the deployment's backend set and
        // each model's backend preference list. A model whose
        // preferences match no pod class in this fleet can never be
        // placed — boot anyway (the ablation baselines need it) but say
        // so loudly.
        let backend_registry = Arc::new(BackendRegistry::from_config(&cfg.engines));
        let engine_catalog = Arc::new(EngineCatalog::resolve(&cfg.server.models, &cfg.engines));
        {
            let mut fleet_backends: Vec<String> = backend_registry
                .for_class(AcceleratorClass::Gpu)
                .iter()
                .map(|b| b.name().to_string())
                .collect();
            if cfg.engines.cpu_replicas > 0 {
                fleet_backends.extend(
                    backend_registry
                        .for_class(AcceleratorClass::Cpu)
                        .iter()
                        .map(|b| b.name().to_string()),
                );
            }
            for m in &cfg.server.models {
                let hostable = engine_catalog
                    .backends_for(&m.name)
                    .iter()
                    .any(|b| fleet_backends.contains(b));
                if !hostable {
                    log::warn!(
                        "model '{}' prefers backends {:?} but no pod class in this \
                         fleet provides one: it will stay unplaceable (add \
                         engines.cpu_replicas or widen server.models[].backends)",
                        m.name,
                        engine_catalog.backends_for(&m.name),
                    );
                }
            }
            // The global autoscaler's trigger metrics aggregate the
            // whole fleet, CPU pods included, but its decisions only
            // resize the GPU group — on a mixed fleet the signal is
            // diluted by capacity scaling cannot touch, unless the
            // class-partitioned CPU scaler (`engines.cpu_max_replicas`)
            // is managing the CPU group from its own trigger. (CPU-only
            // models under an enabled autoscaler are rejected by
            // validation; this is the softer all-models-GPU-capable
            // case.)
            if cfg.autoscaler.enabled
                && !cfg.autoscaler.per_model.enabled
                && cfg.engines.cpu_replicas > 0
                && !cfg.engines.cpu_scaling_enabled()
            {
                log::warn!(
                    "global autoscaler on a mixed fleet: trigger metrics average \
                     over {} CPU pod(s) whose capacity scaling cannot change — \
                     expect a diluted signal (set engines.cpu_max_replicas to put \
                     the CPU group under its own class-partitioned trigger)",
                    cfg.engines.cpu_replicas
                );
            }
        }

        // Modelmesh: per-model routing + placement state, when enabled.
        let mesh_catalog: Option<Vec<(String, u64)>> = if cfg.model_placement.mesh_enabled() {
            let catalog: Vec<(String, u64)> = serving_names
                .iter()
                .map(|n| {
                    let entry = repository.get(n).expect("model just loaded");
                    (n.clone(), entry.memory_bytes())
                })
                .collect();
            let budget = cfg.model_placement.budget_bytes();
            if budget > 0 {
                for (name, mem) in &catalog {
                    anyhow::ensure!(
                        *mem <= budget,
                        "model '{name}' needs {mem} bytes but \
                         model_placement.memory_budget_mb allows only {budget} \
                         bytes per instance",
                    );
                }
            }
            Some(catalog)
        } else {
            None
        };
        let router = mesh_catalog.as_ref().map(|_| {
            Arc::new(ModelRouter::new(
                &serving_names,
                cfg.gateway.lb_policy,
                cfg.gateway.max_inflight_per_instance,
                &registry,
                0x4D455348, // "MESH"
            ))
        });
        // Version routing state: the bare name defaults to the incumbent,
        // a configured canary installs the weighted split, and a pin
        // overrides both (the operator's manual escape hatch).
        if let Some(r) = &router {
            for m in &cfg.server.models {
                let Some(inc) = m.incumbent_version() else { continue };
                let inc_name = versioned_name(&m.name, inc);
                r.set_version_default(&m.name, &inc_name);
                if let Some(c) = &m.canary {
                    r.set_canary(
                        &m.name,
                        &inc_name,
                        &versioned_name(&m.name, c.version),
                        c.weight,
                        0x43414E52, // "CANR"
                    );
                }
                if let Some(p) = m.pinned_version {
                    r.pin_version(&m.name, &versioned_name(&m.name, p));
                }
            }
        }

        // Resolve each served model's effective warm-load delay once
        // (per-model override falling back to model_placement.load_delay)
        // so the instances and the placement controller price the same
        // load.
        let mut resolved_models = serving_models;
        for m in &mut resolved_models {
            m.load_delay = Some(cfg.effective_load_delay(m));
        }
        let load_costs: BTreeMap<String, f64> = resolved_models
            .iter()
            .map(|m| (m.name.clone(), m.load_delay.unwrap_or_default().as_secs_f64()))
            .collect();

        // Instance factory: what the cluster runs on each pod start. With
        // the mesh active, each new pod gets its initial placement
        // (balanced rotation under the memory budget) before it is marked
        // Ready by the cluster.
        let factory: InstanceFactory = {
            let repo = Arc::clone(&repository);
            let models = resolved_models;
            let clock = clock.clone();
            let registry = registry.clone();
            let base_opts = crate::server::InstanceOptions {
                queue_capacity: cfg.server.queue_capacity,
                util_window: cfg.server.util_window,
                exec_mode: cfg.server.execution,
                batch_mode: cfg.server.batch_mode,
                max_bulk_wait: cfg.server.priorities.max_bulk_wait,
                catalog: Arc::clone(&engine_catalog),
                // Shared with the gateway: server-side queue/batch/
                // compute spans land in the same trace buffer the
                // gateway reads its stage breakdown from.
                tracer: tracer.clone(),
                ..Default::default()
            };
            let backend_registry = Arc::clone(&backend_registry);
            let engine_catalog = Arc::clone(&engine_catalog);
            let mesh = mesh_catalog
                .clone()
                .map(|catalog| (catalog, cfg.model_placement.budget_bytes()));
            let placement_seq = Arc::new(AtomicUsize::new(0));
            let rpc_cfg = cfg.rpc.clone();
            Arc::new(move |name: &str, profile: Option<&str>, accel: AcceleratorClass| {
                // The pod's accelerator class fixes its backend set.
                let backends = backend_registry.for_class(accel);
                let backend_names: Vec<String> =
                    backends.iter().map(|b| b.name().to_string()).collect();
                let opts = crate::server::InstanceOptions { backends, ..base_opts.clone() };
                let inst = Instance::start_with_opts(
                    name,
                    Arc::clone(&repo),
                    &models,
                    clock.clone(),
                    registry.clone(),
                    opts,
                );
                if let Some((catalog, budget)) = &mesh {
                    match profile {
                        // Boot profile (per-model autoscaling): the pod
                        // was spawned for one model and advertises only
                        // it. Placement may load more onto it later. The
                        // profile names the *base* model; the repository
                        // resolves it to the current incumbent version,
                        // so pods booting after a promotion come up on
                        // the new version without a respawn (the
                        // make-before-break boot-profile retag).
                        Some(model) => {
                            inst.set_loaded_models(&[repo.serving_name(model)])
                        }
                        // The rotation index is a plain counter, so a pod
                        // replacing a failed one may boot with a different
                        // slot than the pod it replaces. That is fine: the
                        // placement controller's min-replica repair pass
                        // (which runs under static policy too) re-hosts any
                        // model the churn left without a replica.
                        None => {
                            // Rotate only over the models this pod's
                            // backend set can actually serve, so a CPU
                            // pod's boot placement is not wasted on
                            // GPU-only models — and only over versions a
                            // rollout actively serves (incumbent, canary,
                            // pin): spare versions stay registered but
                            // boot cold.
                            let hostable: Vec<(String, u64)> = catalog
                                .iter()
                                .filter(|(m, _)| active_serving.contains(m))
                                .filter(|(m, _)| {
                                    engine_catalog
                                        .backends_for(m)
                                        .iter()
                                        .any(|b| backend_names.contains(b))
                                })
                                .cloned()
                                .collect();
                            let idx = placement_seq.fetch_add(1, Ordering::SeqCst);
                            inst.set_loaded_models(&initial_placement(&hostable, *budget, idx));
                        }
                    }
                }
                if rpc_cfg.remote_dispatch {
                    // Remote dispatch: every pod exposes a sonic-rpc
                    // endpoint (ephemeral port) for the gateway's session
                    // pool to dial; demultiplexed so pooled sessions can
                    // pipeline into it.
                    let opts = crate::rpc::RpcServerOpts {
                        workers: 2,
                        max_connections: 0,
                        max_inflight_per_conn: rpc_cfg.max_inflight_per_conn,
                        dispatch_threads: rpc_cfg.dispatch_threads.max(1),
                    };
                    if let Err(e) = inst.serve_rpc("127.0.0.1:0", opts) {
                        eprintln!("[deployment] pod {name}: rpc endpoint failed: {e:#}");
                    }
                }
                inst
            })
        };

        let initial = if cfg.autoscaler.enabled {
            cfg.server.replicas.clamp(cfg.autoscaler.min_replicas, cfg.autoscaler.max_replicas)
        } else {
            cfg.server.replicas
        };
        let per_model_on = cfg.autoscaler.enabled && cfg.autoscaler.per_model.enabled;
        let cluster = if per_model_on {
            // Per-model pod targets: the initial replica count spread
            // round-robin over the catalog, clamped to each model's
            // bounds. Each pod carries its model as a boot profile.
            let targets =
                initial_model_targets(initial, &model_names, &cfg.autoscaler.per_model);
            let cluster = Cluster::start_per_model(
                cfg.cluster.clone(),
                cfg.server.startup_delay,
                targets,
                clock.clone(),
                registry.clone(),
                factory,
                0x5057E5,
            );
            // The CPU-class group converges next to the per-model GPU
            // groups (per-model targets never cover CPU pods).
            cluster.set_cpu_desired(cfg.engines.cpu_replicas);
            cluster
        } else {
            Cluster::start_with_cpu(
                cfg.cluster.clone(),
                cfg.server.startup_delay,
                initial,
                cfg.engines.cpu_replicas,
                clock.clone(),
                registry.clone(),
                factory,
                0x5057E5,
            )
        };
        if cfg.model_placement.mesh_enabled() {
            // Scale-down victim selection must respect the placement
            // floor: never kill the pod that holds a model's last
            // min-replica copy while a redundant victim exists.
            cluster.set_victim_floor(cfg.model_placement.min_replicas_per_model);
        }

        // Optional external-metric pressure gate: shed while average queue
        // latency exceeds 20x the autoscaler threshold (i.e. the system is
        // far beyond what scaling can absorb). Only armed when rate
        // limiting is configured, mirroring the chart's opt-in limits.
        let pressure = if cfg.gateway.rate_limit_rps > 0.0 {
            let store2 = store.clone();
            let threshold = cfg.autoscaler.threshold * 20.0;
            Some(PressureGate::new(
                Box::new(move || {
                    store2.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0)
                }),
                threshold,
            ))
        } else {
            None
        };

        let gateway = Gateway::start_full(
            &cfg.gateway,
            cluster.endpoints_handle(),
            clock.clone(),
            registry.clone(),
            tracer.clone(),
            pressure,
            router.clone(),
            cfg.server.priorities.clone(),
            &cfg.rpc,
        )?;

        // Placement controller rides the cluster reconcile loop: pools
        // follow pod churn every pass, and (dynamic policy) models move
        // toward demand.
        let placement = match (&mesh_catalog, &router) {
            (Some(catalog), Some(router)) => {
                // Versioned entries inherit the base model's backend
                // preferences in the planner's compat map (the engine
                // catalog already resolves versioned lookups that way).
                let mut compat = engine_catalog.compat_map();
                for (name, _) in catalog {
                    let (base, v) = split_version(name);
                    if v.is_some() && !compat.contains_key(name) {
                        if let Some(prefs) = compat.get(base).cloned() {
                            compat.insert(name.clone(), prefs);
                        }
                    }
                }
                let controller = PlacementController::new(
                    cfg.model_placement.clone(),
                    catalog.clone(),
                    load_costs.clone(),
                    compat,
                    cfg.engines.onnx_slowdown,
                    Arc::clone(router),
                    store.clone(),
                    clock.clone(),
                    &registry,
                );
                // Spare versions (registered but neither incumbent,
                // canary nor pin) retire toward the incumbent from boot:
                // the planner never grows them and drains any stray copy.
                for m in &cfg.server.models {
                    let Some(inc) = m.incumbent_version() else { continue };
                    for spec in &m.versions {
                        let v = spec.version;
                        let active = v == inc
                            || m.canary.as_ref().is_some_and(|c| c.version == v)
                            || m.pinned_version == Some(v);
                        if !active {
                            controller.set_successor(
                                &versioned_name(&m.name, v),
                                &versioned_name(&m.name, inc),
                            );
                        }
                    }
                }
                let hooked = Arc::clone(&controller);
                cluster.set_reconcile_hook(Arc::new(move |eps| hooked.reconcile(eps)));
                Some(controller)
            }
            _ => None,
        };

        // Per-model autoscaling: one scaling loop per model, fed by the
        // placement controller's demand signal, pushing per-model pod
        // targets into the cluster. The global autoscaler loop is started
        // inert in that case — the per-model loop owns the targets.
        let per_model_scaler = match (&placement, per_model_on) {
            (Some(p), true) => {
                let probe: DemandProbe = {
                    let p = Arc::clone(p);
                    Arc::new(move |model: &str, now: f64| p.demand_for(model, now))
                };
                Some(PerModelScaler::start(
                    cfg.autoscaler.clone(),
                    model_names.clone(),
                    Arc::clone(&cluster),
                    probe,
                    clock.clone(),
                    registry.clone(),
                ))
            }
            _ => None,
        };
        // Class-partitioned CPU autoscaling (`engines.cpu_max_replicas`):
        // a dedicated trigger fed only by the CPU-attributed share of
        // each model's demand drives `Cluster::set_cpu_desired` between
        // the configured floor and ceiling — GPU saturation cannot
        // ratchet CPU pods, and vice versa. Validation guarantees the
        // mesh (and so placement + router) whenever this is enabled.
        let cpu_scaler = match (&placement, &router) {
            (Some(p), Some(r))
                if cfg.autoscaler.enabled && cfg.engines.cpu_scaling_enabled() =>
            {
                let demand: DemandProbe = {
                    let p = Arc::clone(p);
                    Arc::new(move |model: &str, now: f64| p.demand_for(model, now))
                };
                // A model's CPU share is the CPU-class fraction of its
                // warm endpoints: demand on a model served entirely by
                // GPU pods contributes nothing to the CPU trigger.
                let cpu_share: CpuShareProbe = {
                    let r = Arc::clone(r);
                    let repo = Arc::clone(&repository);
                    Arc::new(move |model: &str| {
                        let eps = r.endpoints_for(&repo.serving_name(model));
                        if eps.is_empty() {
                            return 0.0;
                        }
                        let cpu = eps
                            .iter()
                            .filter(|i| !i.backend_names().iter().any(|b| b == "pjrt"))
                            .count();
                        cpu as f64 / eps.len() as f64
                    })
                };
                Some(CpuScaler::start(
                    &cfg.autoscaler,
                    cfg.engines.cpu_replicas,
                    cfg.engines.effective_cpu_max(),
                    model_names.clone(),
                    Arc::clone(&cluster),
                    demand,
                    cpu_share,
                    clock.clone(),
                    registry.clone(),
                ))
            }
            _ => None,
        };
        let mut global_scaler_cfg = cfg.autoscaler.clone();
        if per_model_scaler.is_some() {
            global_scaler_cfg.enabled = false;
        }
        let autoscaler = Autoscaler::start(
            global_scaler_cfg,
            Arc::clone(&cluster),
            store.clone(),
            clock.clone(),
            registry.clone(),
        );

        // SLO burn-rate engine: only when targets are configured. The
        // task evaluates on the shared (possibly dilated) clock, so the
        // fast/slow windows follow the experiment's time scale.
        let (slo, slo_task) = if cfg.observability.slos.is_empty() {
            (None, None)
        } else {
            let engine = Arc::new(SloEngine::new(
                cfg.observability.clone(),
                registry.clone(),
                store.clone(),
                clock.clone(),
            ));
            let task = SloTask::start(
                Arc::clone(&engine),
                clock.clone(),
                cfg.observability.slo_eval_interval,
            );
            (Some(engine), Some(task))
        };

        // Canary auto-rollback: armed when any model configures a canary
        // split. The probe reads the router's live split set (promotions
        // and manual clears are picked up on the next evaluation); the
        // action tears the split down and retires the canary through the
        // placement controller's make-before-break path.
        let any_canary = cfg.server.models.iter().any(|m| m.canary.is_some());
        let (rollback, rollback_task) = match (&router, any_canary) {
            (Some(r), true) => {
                let bases: Vec<String> = cfg
                    .server
                    .models
                    .iter()
                    .filter(|m| m.canary.is_some())
                    .map(|m| m.name.clone())
                    .collect();
                let probe: CanaryProbe = {
                    let router = Arc::clone(r);
                    Box::new(move || {
                        bases
                            .iter()
                            .filter_map(|b| {
                                router.canary_of(b).map(|(incumbent, canary, _)| {
                                    CanarySnapshot {
                                        base: b.clone(),
                                        incumbent,
                                        canary,
                                    }
                                })
                            })
                            .collect()
                    })
                };
                let action: RollbackAction = {
                    let router = Arc::clone(r);
                    let placement = placement.clone();
                    Box::new(move |snap: &CanarySnapshot| {
                        log::warn!(
                            "canary auto-rollback: '{}' reverts to '{}'",
                            snap.base,
                            snap.incumbent
                        );
                        router.clear_canary(&snap.base);
                        if let Some(p) = &placement {
                            p.set_successor(&snap.canary, &snap.incumbent);
                        }
                    })
                };
                let engine = Arc::new(RollbackEngine::new(
                    cfg.observability.clone(),
                    registry.clone(),
                    store.clone(),
                    clock.clone(),
                    probe,
                    action,
                ));
                let task = RollbackTask::start(
                    Arc::clone(&engine),
                    clock.clone(),
                    cfg.observability.slo_eval_interval,
                );
                (Some(engine), Some(task))
            }
            _ => (None, None),
        };

        // Staged canary ramps: one clock loop per model with a
        // configured `canary.ramp`, advancing the split stage by stage
        // while the rollback evaluator stays quiet for the model.
        let ramp_tasks = match &router {
            Some(r) => Self::start_ramp_tasks(
                &cfg,
                vec![Arc::clone(r)],
                rollback.clone(),
                &clock,
                &registry,
            ),
            None => Vec::new(),
        };

        // Point every control loop's recorder handle at the shared ring.
        if let Some(f) = &flight {
            if let Some(p) = &placement {
                p.recorder().install(Arc::clone(f));
            }
            if let Some(s) = &per_model_scaler {
                s.recorder().install(Arc::clone(f));
            }
            if let Some(s) = &cpu_scaler {
                s.recorder().install(Arc::clone(f));
            }
            autoscaler.recorder().install(Arc::clone(f));
            if let Some(rb) = &rollback {
                rb.recorder().install(Arc::clone(f));
            }
            for t in &ramp_tasks {
                t.recorder().install(Arc::clone(f));
            }
        }

        let metrics_http = if cfg.monitoring.listen.is_empty() {
            None
        } else {
            let debug: Option<DebugProvider> = flight.as_ref().map(|f| {
                let f = Arc::clone(f);
                Arc::new(move || f.explain(&ExplainFilter::default())) as DebugProvider
            });
            Some(MetricsServer::start_with_debug(
                &cfg.monitoring.listen,
                registry.clone(),
                debug,
            )?)
        };

        log::info!(
            "deployment '{}' up: {} models, {} initial replicas, lb={}, autoscaler={}, placement={}",
            cfg.name,
            model_names.len(),
            cluster.desired(),
            cfg.gateway.lb_policy.name(),
            if !cfg.autoscaler.enabled {
                "off"
            } else if per_model_on {
                "per-model"
            } else {
                "on"
            },
            if cfg.model_placement.mesh_enabled() {
                cfg.model_placement.policy.name()
            } else {
                "off"
            },
        );

        Ok(Deployment {
            cfg,
            clock,
            registry,
            store,
            tracer,
            repository,
            cluster,
            gateway,
            autoscaler,
            per_model_scaler,
            router,
            placement,
            slo,
            rollback,
            federation: None,
            cpu_scaler,
            flight,
            ramp_tasks,
            metrics_http,
            _slo_task: slo_task,
            _rollback_task: rollback_task,
            _scraper: scraper,
        })
    }

    /// One [`RampTask`] per model with a configured `canary.ramp`. In
    /// federated mode `routers` carries every site's router with the
    /// policy (gateway-site) router first; the task advances the split
    /// on all of them in lock-step.
    fn start_ramp_tasks(
        cfg: &DeploymentConfig,
        routers: Vec<Arc<ModelRouter>>,
        rollback: Option<Arc<RollbackEngine>>,
        clock: &Clock,
        registry: &Registry,
    ) -> Vec<RampTask> {
        let mut tasks = Vec::new();
        for m in &cfg.server.models {
            let Some(c) = &m.canary else { continue };
            if c.ramp.is_empty() {
                continue;
            }
            let Some(inc) = m.incumbent_version() else { continue };
            tasks.push(RampTask::start(
                routers.clone(),
                m.name.clone(),
                versioned_name(&m.name, inc),
                versioned_name(&m.name, c.version),
                c.ramp.clone(),
                c.ramp_interval,
                c.weight,
                0x43414E52, // "CANR" — same split hash as the initial install
                rollback.clone(),
                clock.clone(),
                registry,
            ));
        }
        tasks
    }

    /// Boot a multi-site federation (`federation.sites` non-empty): one
    /// full site control plane — cluster, mesh router, placement loop,
    /// per-model scaler — per configured site, a federation-tier router
    /// in front of them, the global budget rebalancer, and ONE gateway
    /// homed at `federation.gateway_site`. The single-cluster fields of
    /// the returned [`Deployment`] alias the gateway site's components.
    fn up_federated(cfg: DeploymentConfig) -> Result<Self> {
        let clock = if (cfg.time_scale - 1.0).abs() < f64::EPSILON {
            Clock::real()
        } else {
            Clock::scaled(cfg.time_scale)
        };
        let registry = Registry::new();
        let store = MetricStore::new(cfg.monitoring.retention);
        let scraper = Scraper::start(
            registry.clone(),
            store.clone(),
            clock.clone(),
            cfg.monitoring.scrape_interval,
        );
        let tracer = if cfg.monitoring.tracing {
            Tracer::new(clock.clone(), cfg.observability.trace_capacity, true)
                .with_sample_rate(cfg.observability.trace_sample_rate)
        } else {
            Tracer::disabled()
        };
        tracer.bind_registry(&registry);

        // Control-plane flight recorder (installed into every site's
        // loops plus the federation tier below).
        let flight = (cfg.observability.flight_recorder_capacity > 0).then(|| {
            Arc::new(FlightRecorder::new(
                clock.clone(),
                cfg.observability.flight_recorder_capacity,
                cfg.observability.explain_horizon.as_secs_f64(),
                registry.clone(),
            ))
        });

        let model_names: Vec<String> =
            cfg.server.models.iter().map(|m| m.name.clone()).collect();
        let repository = Arc::new(match cfg.server.execution {
            ExecutionMode::Real => {
                let runtime = PjrtRuntime::cpu().context("creating PJRT client")?;
                ModelRepository::load(&runtime, &cfg.server.repository, &model_names)?
            }
            ExecutionMode::Simulated => {
                ModelRepository::load_metadata(&cfg.server.repository, &model_names)?
            }
        });

        // Version expansion: identical to the single-cluster path — the
        // same servable set exists at every site.
        let mut serving_models: Vec<ModelConfig> = Vec::new();
        for m in &cfg.server.models {
            if m.versions.is_empty() {
                serving_models.push(m.clone());
                continue;
            }
            for spec in &m.versions {
                repository.register_version(&m.name, spec.version)?;
                let mut vm = m.clone();
                vm.name = versioned_name(&m.name, spec.version);
                vm.versions = Vec::new();
                vm.incumbent = None;
                vm.canary = None;
                vm.pinned_version = None;
                if (spec.slowdown - 1.0).abs() > f64::EPSILON {
                    let scale = |d: Duration| Duration::from_secs_f64(d.as_secs_f64() * spec.slowdown);
                    vm.service_model.base = scale(vm.service_model.base);
                    vm.service_model.per_row = scale(vm.service_model.per_row);
                }
                serving_models.push(vm);
            }
            if let Some(v) = m.incumbent_version() {
                repository.set_incumbent(&m.name, v);
            }
        }
        let serving_names: Vec<String> =
            serving_models.iter().map(|m| m.name.clone()).collect();
        let active_serving: std::collections::BTreeSet<String> = cfg
            .server
            .models
            .iter()
            .flat_map(|m| {
                if m.versions.is_empty() {
                    return vec![m.name.clone()];
                }
                let mut active: Vec<String> = Vec::new();
                if let Some(v) = m.incumbent_version() {
                    active.push(versioned_name(&m.name, v));
                }
                if let Some(c) = &m.canary {
                    active.push(versioned_name(&m.name, c.version));
                }
                if let Some(p) = m.pinned_version {
                    active.push(versioned_name(&m.name, p));
                }
                active
            })
            .collect();

        let backend_registry = Arc::new(BackendRegistry::from_config(&cfg.engines));
        let engine_catalog = Arc::new(EngineCatalog::resolve(&cfg.server.models, &cfg.engines));
        {
            // CPU groups are sized per site in federated mode.
            let any_cpu = cfg.federation.sites.iter().any(|s| s.cpu_replicas > 0);
            let mut fleet_backends: Vec<String> = backend_registry
                .for_class(AcceleratorClass::Gpu)
                .iter()
                .map(|b| b.name().to_string())
                .collect();
            if any_cpu {
                fleet_backends.extend(
                    backend_registry
                        .for_class(AcceleratorClass::Cpu)
                        .iter()
                        .map(|b| b.name().to_string()),
                );
            }
            for m in &cfg.server.models {
                let hostable = engine_catalog
                    .backends_for(&m.name)
                    .iter()
                    .any(|b| fleet_backends.contains(b));
                if !hostable {
                    log::warn!(
                        "model '{}' prefers backends {:?} but no pod class in this \
                         federation provides one: it will stay unplaceable (add \
                         federation.sites[].cpu_replicas or widen \
                         server.models[].backends)",
                        m.name,
                        engine_catalog.backends_for(&m.name),
                    );
                }
            }
        }

        // Federation validation guarantees the mesh, so the placement
        // catalog always exists here.
        let catalog: Vec<(String, u64)> = serving_names
            .iter()
            .map(|n| {
                let entry = repository.get(n).expect("model just loaded");
                (n.clone(), entry.memory_bytes())
            })
            .collect();
        let budget = cfg.model_placement.budget_bytes();
        if budget > 0 {
            for (name, mem) in &catalog {
                anyhow::ensure!(
                    *mem <= budget,
                    "model '{name}' needs {mem} bytes but \
                     model_placement.memory_budget_mb allows only {budget} \
                     bytes per instance",
                );
            }
        }

        let mut resolved_models = serving_models;
        for m in &mut resolved_models {
            m.load_delay = Some(cfg.effective_load_delay(m));
        }
        let load_costs: BTreeMap<String, f64> = resolved_models
            .iter()
            .map(|m| (m.name.clone(), m.load_delay.unwrap_or_default().as_secs_f64()))
            .collect();

        // ONE instance factory shared by every site's cluster: pods come
        // up with site-prefixed names (the cluster adds the prefix) but
        // identical serving behavior. The boot-rotation counter is
        // shared too, so initial placements stay balanced federation-
        // wide rather than identical per site.
        let factory: InstanceFactory = {
            let repo = Arc::clone(&repository);
            let models = resolved_models;
            let clock = clock.clone();
            let registry = registry.clone();
            let base_opts = crate::server::InstanceOptions {
                queue_capacity: cfg.server.queue_capacity,
                util_window: cfg.server.util_window,
                exec_mode: cfg.server.execution,
                batch_mode: cfg.server.batch_mode,
                max_bulk_wait: cfg.server.priorities.max_bulk_wait,
                catalog: Arc::clone(&engine_catalog),
                tracer: tracer.clone(),
                ..Default::default()
            };
            let backend_registry = Arc::clone(&backend_registry);
            let engine_catalog = Arc::clone(&engine_catalog);
            let mesh = Some((catalog.clone(), budget));
            let placement_seq = Arc::new(AtomicUsize::new(0));
            let rpc_cfg = cfg.rpc.clone();
            let active_serving = active_serving.clone();
            Arc::new(move |name: &str, profile: Option<&str>, accel: AcceleratorClass| {
                let backends = backend_registry.for_class(accel);
                let backend_names: Vec<String> =
                    backends.iter().map(|b| b.name().to_string()).collect();
                let opts = crate::server::InstanceOptions { backends, ..base_opts.clone() };
                let inst = Instance::start_with_opts(
                    name,
                    Arc::clone(&repo),
                    &models,
                    clock.clone(),
                    registry.clone(),
                    opts,
                );
                if let Some((catalog, budget)) = &mesh {
                    match profile {
                        Some(model) => {
                            inst.set_loaded_models(&[repo.serving_name(model)])
                        }
                        None => {
                            let hostable: Vec<(String, u64)> = catalog
                                .iter()
                                .filter(|(m, _)| active_serving.contains(m))
                                .filter(|(m, _)| {
                                    engine_catalog
                                        .backends_for(m)
                                        .iter()
                                        .any(|b| backend_names.contains(b))
                                })
                                .cloned()
                                .collect();
                            let idx = placement_seq.fetch_add(1, Ordering::SeqCst);
                            inst.set_loaded_models(&initial_placement(&hostable, *budget, idx));
                        }
                    }
                }
                if rpc_cfg.remote_dispatch {
                    let opts = crate::rpc::RpcServerOpts {
                        workers: 2,
                        max_connections: 0,
                        max_inflight_per_conn: rpc_cfg.max_inflight_per_conn,
                        dispatch_threads: rpc_cfg.dispatch_threads.max(1),
                    };
                    if let Err(e) = inst.serve_rpc("127.0.0.1:0", opts) {
                        eprintln!("[deployment] pod {name}: rpc endpoint failed: {e:#}");
                    }
                }
                inst
            })
        };

        // Versioned compat inheritance, shared by every site's planner.
        let mut compat = engine_catalog.compat_map();
        for (name, _) in &catalog {
            let (base, v) = split_version(name);
            if v.is_some() && !compat.contains_key(name) {
                if let Some(prefs) = compat.get(base).cloned() {
                    compat.insert(name.clone(), prefs);
                }
            }
        }

        // Per-site control planes. Every site's router installs the SAME
        // version-routing state with the SAME canary hash seed, so a
        // request hashes to the same version at whichever site serves it.
        let mut sites: Vec<Arc<Site>> = Vec::new();
        for (i, sc) in cfg.federation.sites.iter().enumerate() {
            let router = Arc::new(ModelRouter::new_for_site(
                &serving_names,
                cfg.gateway.lb_policy,
                cfg.gateway.max_inflight_per_instance,
                &registry,
                0x4D455348 ^ i as u64, // "MESH" + site index
                &sc.name,
            ));
            for m in &cfg.server.models {
                let Some(inc) = m.incumbent_version() else { continue };
                let inc_name = versioned_name(&m.name, inc);
                router.set_version_default(&m.name, &inc_name);
                if let Some(c) = &m.canary {
                    router.set_canary(
                        &m.name,
                        &inc_name,
                        &versioned_name(&m.name, c.version),
                        c.weight,
                        0x43414E52, // "CANR" — identical at every site
                    );
                }
                if let Some(p) = m.pinned_version {
                    router.pin_version(&m.name, &versioned_name(&m.name, p));
                }
            }

            let site_cluster_cfg = ClusterConfig {
                nodes: sc.nodes,
                gpus_per_node: sc.gpus_per_node,
                pod_start_delay: cfg.cluster.pod_start_delay,
                termination_grace: cfg.cluster.termination_grace,
                pod_failure_rate: cfg.cluster.pod_failure_rate,
            };
            let targets =
                initial_model_targets(sc.replicas, &model_names, &cfg.autoscaler.per_model);
            let cluster = Cluster::start_per_model_site(
                site_cluster_cfg,
                cfg.server.startup_delay,
                targets,
                sc.cpu_replicas,
                &sc.name,
                clock.clone(),
                registry.clone(),
                Arc::clone(&factory),
                0x5057E5 ^ i as u64,
            );
            cluster.set_victim_floor(cfg.model_placement.min_replicas_per_model);

            let placement = PlacementController::new_for_site(
                cfg.model_placement.clone(),
                catalog.clone(),
                load_costs.clone(),
                compat.clone(),
                cfg.engines.onnx_slowdown,
                Arc::clone(&router),
                store.clone(),
                clock.clone(),
                &registry,
                &sc.name,
            );
            for m in &cfg.server.models {
                let Some(inc) = m.incumbent_version() else { continue };
                for spec in &m.versions {
                    let v = spec.version;
                    let active = v == inc
                        || m.canary.as_ref().is_some_and(|c| c.version == v)
                        || m.pinned_version == Some(v);
                    if !active {
                        placement.set_successor(
                            &versioned_name(&m.name, v),
                            &versioned_name(&m.name, inc),
                        );
                    }
                }
            }
            let hooked = Arc::clone(&placement);
            cluster.set_reconcile_hook(Arc::new(move |eps| hooked.reconcile(eps)));

            // The site-local scaler's pod budget starts at the site's
            // configured slice; the rebalancer moves it afterwards.
            let mut scaler_cfg = cfg.autoscaler.clone();
            scaler_cfg.max_replicas = sc.pod_budget;
            let probe: DemandProbe = {
                let p = Arc::clone(&placement);
                Arc::new(move |model: &str, now: f64| p.demand_for(model, now))
            };
            let scaler = PerModelScaler::start_for_site(
                scaler_cfg,
                model_names.clone(),
                Arc::clone(&cluster),
                probe,
                clock.clone(),
                registry.clone(),
                &sc.name,
            );

            sites.push(Site::new(
                sc.name.clone(),
                cluster,
                router,
                placement,
                scaler,
                sc.pod_budget,
                cfg.autoscaler.per_model.min_replicas,
                model_names.clone(),
            ));
        }

        let pairs: Vec<(String, Arc<ModelRouter>)> = sites
            .iter()
            .map(|s| (s.name.clone(), Arc::clone(&s.router)))
            .collect();
        let fed_router = FederationRouter::new(&cfg.federation, &pairs, &registry);
        let rebalancer =
            Rebalancer::start(&cfg.federation, sites.clone(), clock.clone(), &registry);

        let gateway_site = cfg.federation.gateway_site().to_string();
        let home = sites
            .iter()
            .position(|s| s.name == gateway_site)
            .unwrap_or(0);

        let pressure = if cfg.gateway.rate_limit_rps > 0.0 {
            let store2 = store.clone();
            let threshold = cfg.autoscaler.threshold * 20.0;
            Some(PressureGate::new(
                Box::new(move || {
                    store2.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0)
                }),
                threshold,
            ))
        } else {
            None
        };
        let gateway = Gateway::start_federated(
            &cfg.gateway,
            sites[home].cluster.endpoints_handle(),
            clock.clone(),
            registry.clone(),
            tracer.clone(),
            pressure,
            Arc::clone(&fed_router),
            cfg.server.priorities.clone(),
            &cfg.rpc,
        )?;

        // The global single-cluster autoscaler has no role here: the
        // site-local per-model scalers + the rebalancer own capacity.
        // It is started inert so the Deployment surface stays uniform.
        let mut global_scaler_cfg = cfg.autoscaler.clone();
        global_scaler_cfg.enabled = false;
        let autoscaler = Autoscaler::start(
            global_scaler_cfg,
            Arc::clone(&sites[home].cluster),
            store.clone(),
            clock.clone(),
            registry.clone(),
        );

        let (slo, slo_task) = if cfg.observability.slos.is_empty() {
            (None, None)
        } else {
            let engine = Arc::new(SloEngine::new(
                cfg.observability.clone(),
                registry.clone(),
                store.clone(),
                clock.clone(),
            ));
            let task = SloTask::start(
                Arc::clone(&engine),
                clock.clone(),
                cfg.observability.slo_eval_interval,
            );
            (Some(engine), Some(task))
        };

        // Auto-rollback reads the policy (gateway-site) router's split
        // set and tears a bad canary down at EVERY site.
        let any_canary = cfg.server.models.iter().any(|m| m.canary.is_some());
        let (rollback, rollback_task) = if any_canary {
            let bases: Vec<String> = cfg
                .server
                .models
                .iter()
                .filter(|m| m.canary.is_some())
                .map(|m| m.name.clone())
                .collect();
            let probe: CanaryProbe = {
                let router = Arc::clone(fed_router.policy_router());
                Box::new(move || {
                    bases
                        .iter()
                        .filter_map(|b| {
                            router.canary_of(b).map(|(incumbent, canary, _)| {
                                CanarySnapshot {
                                    base: b.clone(),
                                    incumbent,
                                    canary,
                                }
                            })
                        })
                        .collect()
                })
            };
            let action: RollbackAction = {
                let sites = sites.clone();
                Box::new(move |snap: &CanarySnapshot| {
                    log::warn!(
                        "canary auto-rollback: '{}' reverts to '{}' (all sites)",
                        snap.base,
                        snap.incumbent
                    );
                    for s in &sites {
                        s.router.clear_canary(&snap.base);
                        s.placement.set_successor(&snap.canary, &snap.incumbent);
                    }
                })
            };
            let engine = Arc::new(RollbackEngine::new(
                cfg.observability.clone(),
                registry.clone(),
                store.clone(),
                clock.clone(),
                probe,
                action,
            ));
            let task = RollbackTask::start(
                Arc::clone(&engine),
                clock.clone(),
                cfg.observability.slo_eval_interval,
            );
            (Some(engine), Some(task))
        } else {
            (None, None)
        };

        // Canary ramps advance the split on every site's router in
        // lock-step; the policy router leads (it is the split of record).
        let mut ramp_routers: Vec<Arc<ModelRouter>> =
            vec![Arc::clone(fed_router.policy_router())];
        for s in &sites {
            if !Arc::ptr_eq(&s.router, fed_router.policy_router()) {
                ramp_routers.push(Arc::clone(&s.router));
            }
        }
        let ramp_tasks =
            Self::start_ramp_tasks(&cfg, ramp_routers, rollback.clone(), &clock, &registry);

        // Point every control loop — per site and federation-tier — at
        // the shared flight-recorder ring.
        if let Some(f) = &flight {
            for s in &sites {
                s.placement.recorder().install(Arc::clone(f));
                s.scaler.recorder().install(Arc::clone(f));
            }
            fed_router.recorder().install(Arc::clone(f));
            rebalancer.recorder().install(Arc::clone(f));
            autoscaler.recorder().install(Arc::clone(f));
            if let Some(rb) = &rollback {
                rb.recorder().install(Arc::clone(f));
            }
            for t in &ramp_tasks {
                t.recorder().install(Arc::clone(f));
            }
        }

        let metrics_http = if cfg.monitoring.listen.is_empty() {
            None
        } else {
            let debug: Option<DebugProvider> = flight.as_ref().map(|f| {
                let f = Arc::clone(f);
                Arc::new(move || f.explain(&ExplainFilter::default())) as DebugProvider
            });
            Some(MetricsServer::start_with_debug(
                &cfg.monitoring.listen,
                registry.clone(),
                debug,
            )?)
        };

        log::info!(
            "deployment '{}' up (federated): {} sites, {} models, {} initial pods, gateway@{}",
            cfg.name,
            sites.len(),
            model_names.len(),
            sites.iter().map(|s| s.cluster.desired()).sum::<usize>(),
            gateway_site,
        );

        let federation = Arc::new(Federation {
            sites: sites.clone(),
            router: Arc::clone(&fed_router),
            rebalancer,
        });
        Ok(Deployment {
            cfg,
            clock,
            registry,
            store,
            tracer,
            repository,
            cluster: Arc::clone(&sites[home].cluster),
            gateway,
            autoscaler,
            // Site-local scalers live in `federation.sites`; the
            // single-cluster slot stays empty so teardown is single-owner.
            per_model_scaler: None,
            router: Some(Arc::clone(&sites[home].router)),
            placement: Some(Arc::clone(&sites[home].placement)),
            slo,
            rollback,
            federation: Some(federation),
            cpu_scaler: None,
            flight,
            ramp_tasks,
            metrics_http,
            _slo_task: slo_task,
            _rollback_task: rollback_task,
            _scraper: scraper,
        })
    }

    /// Promote `base`'s live canary to incumbent: the bare name routes to
    /// the new version, the split is torn down, and the old incumbent
    /// retires through the placement controller's make-before-break path
    /// (its last warm copy stays pinned until the new incumbent is warm
    /// somewhere). Returns `false` when no canary split is live for
    /// `base`.
    pub fn promote_canary(&self, base: &str) -> bool {
        let Some(router) = &self.router else {
            return false;
        };
        let Some((incumbent, canary, _)) = router.canary_of(base) else {
            return false;
        };
        let (_, Some(v)) = split_version(&canary) else {
            return false;
        };
        self.repository.set_incumbent(base, v);
        match &self.federation {
            // Federated: promote at every site in one pass, so no site
            // keeps splitting traffic to a retired incumbent.
            Some(f) => {
                for s in &f.sites {
                    s.router.set_version_default(base, &canary);
                    s.router.clear_canary(base);
                    s.placement.set_successor(&incumbent, &canary);
                }
            }
            None => {
                router.set_version_default(base, &canary);
                router.clear_canary(base);
                if let Some(p) = &self.placement {
                    p.set_successor(&incumbent, &canary);
                }
            }
        }
        if let Some(rb) = &self.rollback {
            // A promoted split is finished: re-arm so the *next* canary
            // for this base can auto-roll back too.
            rb.rearm(base);
        }
        log::info!("canary promoted: '{base}' now serves '{canary}'");
        true
    }

    /// Load a config file and boot.
    pub fn up_from_file(path: &std::path::Path) -> Result<Self> {
        let cfg = DeploymentConfig::from_file(path)?;
        Self::up(cfg)
    }

    /// Gateway endpoint ("the single gRPC endpoint", Fig. 1).
    pub fn endpoint(&self) -> String {
        self.gateway.addr().to_string()
    }

    /// `/metrics` HTTP address, when enabled.
    pub fn metrics_endpoint(&self) -> Option<String> {
        self.metrics_http.as_ref().map(|m| m.addr().to_string())
    }

    /// Block until `n` instances are Ready (true) or `timeout` elapses.
    /// In federated mode `n` counts Ready pods across every site.
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> bool {
        match &self.federation {
            None => self.cluster.wait_ready(n, timeout),
            Some(f) => {
                let deadline = std::time::Instant::now() + timeout;
                while std::time::Instant::now() < deadline {
                    if f.running() >= n {
                        return true;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                f.running() >= n
            }
        }
    }

    /// Tear down in reverse boot order (`helm uninstall`).
    pub fn down(self) {
        for t in &self.ramp_tasks {
            t.shutdown();
        }
        if let Some(s) = &self.cpu_scaler {
            s.shutdown();
        }
        if let Some(s) = &self.per_model_scaler {
            s.shutdown();
        }
        self.autoscaler.shutdown();
        self.gateway.shutdown();
        match &self.federation {
            // Federated: every site's scaler + cluster (the aliased
            // gateway-site `cluster` is among them — shut down once).
            Some(f) => f.shutdown(),
            None => self.cluster.shutdown(),
        }
        // scraper + metrics_http stop on drop
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        AutoscalerConfig, ClusterConfig, GatewayConfig, ModelConfig, MonitoringConfig,
        ServerConfig, ServiceModelConfig,
    };
    use crate::rpc::client::RpcClient;
    use crate::rpc::codec::Status;
    use crate::runtime::Tensor;

    fn fast_cfg(execution: ExecutionMode) -> DeploymentConfig {
        DeploymentConfig {
            name: "test".into(),
            server: ServerConfig {
                replicas: 1,
                models: vec![ModelConfig {
                    name: "icecube_cnn".into(),
                    max_queue_delay: Duration::from_millis(1),
                    preferred_batch: 8,
                    service_model: ServiceModelConfig {
                        base: Duration::from_millis(2),
                        per_row: Duration::from_micros(100),
                    },
                    ..ModelConfig::default()
                }],
                repository: "artifacts".into(),
                startup_delay: Duration::from_millis(10),
                execution,
                queue_capacity: 64,
                util_window: 5.0,
                batch_mode: Default::default(),
                priorities: Default::default(),
            },
            gateway: GatewayConfig::default(),
            autoscaler: AutoscalerConfig {
                enabled: false,
                max_replicas: 4, // cluster capacity below
                ..AutoscalerConfig::default()
            },
            cluster: ClusterConfig {
                nodes: 2,
                gpus_per_node: 2,
                pod_start_delay: Duration::from_millis(20),
                termination_grace: Duration::from_millis(20),
                pod_failure_rate: 0.0,
            },
            monitoring: MonitoringConfig {
                listen: String::new(),
                scrape_interval: Duration::from_millis(100),
                retention: Duration::from_secs(600),
                tracing: false,
            },
            model_placement: Default::default(),
            engines: Default::default(),
            observability: Default::default(),
            rpc: Default::default(),
            federation: Default::default(),
            time_scale: 1.0,
        }
    }

    #[test]
    fn boots_and_serves_simulated() {
        let d = Deployment::up(fast_cfg(ExecutionMode::Simulated)).unwrap();
        assert!(d.wait_ready(1, Duration::from_secs(5)));
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        let resp = client.infer("icecube_cnn", Tensor::zeros(vec![2, 16, 16, 3])).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.output.shape(), &[2, 3]);
        d.down();
    }

    #[test]
    #[cfg_attr(
        not(feature = "pjrt"),
        ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
    )]
    fn boots_and_serves_real_pjrt() {
        let d = Deployment::up(fast_cfg(ExecutionMode::Real)).unwrap();
        assert!(d.wait_ready(1, Duration::from_secs(10)));
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        // ones input: real numerics flow through PJRT
        let input = Tensor::new(vec![1, 16, 16, 3], vec![1.0; 16 * 16 * 3]).unwrap();
        let resp = client.infer("icecube_cnn", input).unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.output.shape(), &[1, 3]);
        // real model output is not all zeros
        assert!(resp.output.data().iter().any(|&v| v != 0.0));
        d.down();
    }

    #[test]
    fn remote_dispatch_deployment_serves() {
        // Full stack over the wire twice: client -> gateway over TCP,
        // gateway -> pod over a pooled multiplexed session.
        let mut cfg = fast_cfg(ExecutionMode::Simulated);
        cfg.rpc.remote_dispatch = true;
        cfg.rpc.dispatch_threads = 4;
        let d = Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(1, Duration::from_secs(5)));
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        for _ in 0..3 {
            let resp = client.infer("icecube_cnn", Tensor::zeros(vec![2, 16, 16, 3])).unwrap();
            assert_eq!(resp.status, Status::Ok, "{}", resp.error);
            assert_eq!(resp.output.shape(), &[2, 3]);
        }
        let pool = d.gateway.session_pool().expect("remote dispatch pools sessions");
        assert_eq!(pool.connects(), 1, "routed hops must reuse the warm session");
        d.down();
    }

    #[test]
    fn autoscaler_enabled_boots_at_min() {
        let mut cfg = fast_cfg(ExecutionMode::Simulated);
        cfg.autoscaler.enabled = true;
        cfg.autoscaler.min_replicas = 2;
        cfg.autoscaler.max_replicas = 4;
        cfg.autoscaler.poll_interval = Duration::from_millis(50);
        let d = Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        assert_eq!(d.cluster.desired(), 2);
        d.down();
    }

    #[test]
    fn metrics_endpoint_serves_text() {
        let mut cfg = fast_cfg(ExecutionMode::Simulated);
        cfg.monitoring.listen = "127.0.0.1:0".into();
        let d = Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(1, Duration::from_secs(5)));
        let addr = d.metrics_endpoint().unwrap();
        // minimal HTTP GET
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut body = String::new();
        s.read_to_string(&mut body).unwrap();
        assert!(body.contains("replicas_running"), "{body}");
        d.down();
    }

    #[test]
    fn scraper_populates_store() {
        let d = Deployment::up(fast_cfg(ExecutionMode::Simulated)).unwrap();
        assert!(d.wait_ready(1, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(400));
        assert!(d.store.latest("replicas_running").is_some());
        d.down();
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = fast_cfg(ExecutionMode::Simulated);
        cfg.server.replicas = 0;
        assert!(Deployment::up(cfg).is_err());
    }

    fn two_model_mesh_cfg() -> DeploymentConfig {
        let mut cfg = fast_cfg(ExecutionMode::Simulated);
        cfg.server.replicas = 2;
        cfg.server.models = vec![
            ModelConfig {
                name: "icecube_cnn".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            },
            ModelConfig {
                name: "particlenet".into(),
                max_queue_delay: Duration::from_millis(1),
                preferred_batch: 8,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(2),
                    per_row: Duration::from_micros(100),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            },
        ];
        // Fits either model alone (icecube_cnn ~152 KB, particlenet
        // ~87 KB of f32 weights) but not both: placement must partition.
        cfg.model_placement.memory_budget_mb = 0.2;
        cfg
    }

    #[test]
    fn mesh_static_partitions_and_serves() {
        let d = Deployment::up(two_model_mesh_cfg()).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300)); // one reconcile pass
        let router = d.router.as_ref().unwrap();
        // Balanced rotation: one replica each, on different instances.
        assert_eq!(router.replicas("icecube_cnn"), 1);
        assert_eq!(router.replicas("particlenet"), 1);
        // Both models served through their per-model balancers.
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        let r1 = client.infer("icecube_cnn", Tensor::zeros(vec![2, 16, 16, 3])).unwrap();
        assert_eq!(r1.status, Status::Ok, "{}", r1.error);
        assert_eq!(r1.output.shape(), &[2, 3]);
        let r2 = client.infer("particlenet", Tensor::zeros(vec![2, 64, 7])).unwrap();
        assert_eq!(r2.status, Status::Ok, "{}", r2.error);
        assert_eq!(r2.output.shape(), &[2, 2]);
        // Memory budget respected on every instance.
        let budget = d.cfg.model_placement.budget_bytes();
        for inst in d.cluster.endpoints() {
            assert!(inst.memory_used() <= budget, "{} over budget", inst.id);
        }
        d.down();
    }

    #[test]
    fn mesh_dynamic_policy_boots() {
        let mut cfg = two_model_mesh_cfg();
        cfg.model_placement.policy = crate::config::PlacementPolicy::Dynamic;
        cfg.model_placement.cooldown = Duration::from_millis(200);
        let d = Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        assert!(d.placement.is_some());
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        let r = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(r.status, Status::Ok, "{}", r.error);
        d.down();
    }

    #[test]
    fn mesh_budget_smaller_than_model_rejected() {
        let mut cfg = two_model_mesh_cfg();
        // icecube_cnn alone needs ~152 KB: 0.1 MB cannot host it.
        cfg.model_placement.memory_budget_mb = 0.1;
        assert!(Deployment::up(cfg).is_err());
    }

    #[test]
    fn heterogeneous_fleet_serves_cpu_only_model() {
        // 1 GPU pod + 1 CPU pod; the CNN is CPU-only (backends:
        // [onnx-sim]) so it must land on — and serve from — the CPU pod,
        // while the GNN keeps its GPU replica.
        let mut cfg = two_model_mesh_cfg();
        cfg.server.replicas = 1;
        cfg.server.models[0].backends = vec!["onnx-sim".into()]; // icecube_cnn
        // Both models fit together; the split is backend-driven.
        cfg.model_placement.memory_budget_mb = 0.45;
        cfg.engines.cpu_replicas = 1;
        let d = Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        assert_eq!(d.cluster.running_cpu(), 1);
        std::thread::sleep(Duration::from_millis(300)); // one reconcile pass
        let router = d.router.as_ref().unwrap();
        // The CPU-only model is hosted exactly on onnx-sim-capable pods.
        let cnn_hosts = router.endpoints_for("icecube_cnn");
        assert_eq!(cnn_hosts.len(), 1, "cpu-only model not placed");
        assert!(cnn_hosts[0].backend_names().contains(&"onnx-sim".to_string()));
        assert_eq!(
            cnn_hosts[0].backend_for_model("icecube_cnn").as_deref(),
            Some("onnx-sim")
        );
        // Both models serve end to end through the gateway.
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        let r1 = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(r1.status, Status::Ok, "{}", r1.error);
        assert_eq!(r1.output.shape(), &[1, 3]);
        let r2 = client.infer("particlenet", Tensor::zeros(vec![1, 64, 7])).unwrap();
        assert_eq!(r2.status, Status::Ok, "{}", r2.error);
        d.down();
    }

    fn canary_cfg() -> DeploymentConfig {
        use crate::config::{CanaryConfig, VersionSpec};
        let mut cfg = two_model_mesh_cfg();
        // Both CNN versions (~152 KB each) plus the GNN (~87 KB) fit on
        // one pod together.
        cfg.model_placement.memory_budget_mb = 0.45;
        cfg.server.models[0].versions = vec![
            VersionSpec { version: 1, slowdown: 1.0 },
            VersionSpec { version: 2, slowdown: 1.0 },
        ];
        cfg.server.models[0].canary =
            Some(CanaryConfig { version: 2, weight: 0.5, ..CanaryConfig::default() });
        cfg
    }

    #[test]
    fn canary_deployment_splits_then_promotes() {
        let d = Deployment::up(canary_cfg()).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        assert!(d.rollback.is_some(), "canary config must arm the rollback engine");
        std::thread::sleep(Duration::from_millis(300)); // one reconcile pass
        let router = Arc::clone(d.router.as_ref().unwrap());
        assert!(router.replicas("icecube_cnn@v1") >= 1);
        assert!(router.replicas("icecube_cnn@v2") >= 1);
        assert_eq!(d.repository.incumbent("icecube_cnn"), Some(1));
        // The bare name serves through the live 50/50 split.
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        for _ in 0..32 {
            let r = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
            assert_eq!(r.status, Status::Ok, "{}", r.error);
            assert_eq!(r.output.shape(), &[1, 3]);
        }
        // Promotion tears the split down, advances the incumbent, and
        // keeps the bare name serving (now on v2).
        assert!(d.promote_canary("icecube_cnn"));
        assert!(router.canary_of("icecube_cnn").is_none());
        assert_eq!(d.repository.incumbent("icecube_cnn"), Some(2));
        assert!(!d.promote_canary("icecube_cnn"), "no live split left to promote");
        for _ in 0..8 {
            let r = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
            assert_eq!(r.status, Status::Ok, "{}", r.error);
        }
        d.down();
    }

    #[test]
    fn initial_model_targets_spread_and_clamp() {
        let models = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let pm = PerModelScalingConfig {
            enabled: true,
            threshold: 100.0,
            min_replicas: 1,
            max_replicas: 4,
        };
        let t = initial_model_targets(4, &models, &pm);
        assert_eq!(t["a"], 2);
        assert_eq!(t["b"], 1);
        assert_eq!(t["c"], 1);
        // floors win when the share rounds to zero
        let t = initial_model_targets(1, &models, &pm);
        assert!(t.values().all(|&n| n == 1), "{t:?}");
        // caps win over a large initial count
        let t = initial_model_targets(30, &models, &pm);
        assert!(t.values().all(|&n| n == 4), "{t:?}");
    }

    #[test]
    fn per_model_autoscaling_boots_with_profiles() {
        let mut cfg = two_model_mesh_cfg();
        cfg.server.replicas = 2;
        cfg.autoscaler.enabled = true;
        cfg.autoscaler.min_replicas = 2;
        cfg.autoscaler.max_replicas = 4;
        cfg.autoscaler.per_model = PerModelScalingConfig {
            enabled: true,
            threshold: 1e9, // never scale during this test
            min_replicas: 1,
            max_replicas: 3,
        };
        let d = Deployment::up(cfg).unwrap();
        assert!(d.per_model_scaler.is_some());
        assert!(d.cluster.per_model());
        // one boot-profile pod per model
        assert_eq!(d.cluster.desired_for("icecube_cnn"), 1);
        assert_eq!(d.cluster.desired_for("particlenet"), 1);
        assert!(d.wait_ready(2, Duration::from_secs(5)));
        // every pod advertises exactly the model it was spawned for
        std::thread::sleep(Duration::from_millis(300)); // one reconcile pass
        let router = d.router.as_ref().unwrap();
        assert_eq!(router.replicas("icecube_cnn"), 1);
        assert_eq!(router.replicas("particlenet"), 1);
        // both models serve through their dedicated pods
        let mut client = RpcClient::connect(&d.endpoint()).unwrap();
        let r = client.infer("icecube_cnn", Tensor::zeros(vec![1, 16, 16, 3])).unwrap();
        assert_eq!(r.status, Status::Ok, "{}", r.error);
        let r = client.infer("particlenet", Tensor::zeros(vec![1, 64, 7])).unwrap();
        assert_eq!(r.status, Status::Ok, "{}", r.error);
        // raising one model's target spawns a pod that boots with only
        // that model advertised
        d.cluster.set_desired_for("particlenet", 2);
        assert!(d.wait_ready(3, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(d.router.as_ref().unwrap().replicas("particlenet"), 2);
        d.down();
    }
}
