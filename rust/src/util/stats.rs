//! Latency/throughput statistics: streaming summaries and fixed-bucket
//! histograms (the same exponential-bucket scheme Prometheus uses).

/// Streaming summary: count, mean, min, max plus a bounded reservoir for
/// percentile estimates.
#[derive(Clone, Debug)]
pub struct Summary {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Bounded sample of observations for percentile estimation.
    reservoir: Vec<f64>,
    cap: usize,
    seen: u64,
    /// xorshift state for reservoir sampling (deterministic).
    rng_state: u64,
}

impl Summary {
    /// New summary with the default reservoir size (4096 samples).
    pub fn new() -> Self {
        Self::with_capacity(4096)
    }

    /// New summary with a custom reservoir capacity.
    pub fn with_capacity(cap: usize) -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::with_capacity(cap.min(4096)),
            cap,
            seen: 0,
            rng_state: 0x9E3779B97F4A7C15,
        }
    }

    fn next_rand(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.seen += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(v);
        } else {
            // Vitter's algorithm R.
            let j = (self.next_rand() % self.seen) as usize;
            if j < self.cap {
                self.reservoir[j] = v;
            }
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean, or 0 if empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Minimum, or 0 if empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    /// Maximum, or 0 if empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Percentile estimate from the reservoir (q in [0, 1]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut xs = self.reservoir.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        xs[idx]
    }

    /// Merge another summary into this one (reservoirs concatenated and
    /// re-truncated — adequate for reporting).
    pub fn merge(&mut self, other: &Summary) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for &v in &other.reservoir {
            if self.reservoir.len() < self.cap {
                self.reservoir.push(v);
            }
        }
        self.seen += other.seen;
    }
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

/// Fixed-boundary histogram (cumulative, Prometheus-style).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Histogram {
    /// Histogram with explicit bucket upper bounds (must be sorted).
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], count: 0, sum: 0.0 }
    }

    /// Default latency buckets in seconds: 0.5ms .. ~134s, doubling.
    pub fn latency_seconds() -> Self {
        let mut bounds = Vec::new();
        let mut b = 0.0005;
        for _ in 0..18 {
            bounds.push(b);
            b *= 2.0;
        }
        Histogram::new(bounds)
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        let idx = match self
            .bounds
            .iter()
            .position(|&b| v <= b)
        {
            Some(i) => i,
            None => self.bounds.len(),
        };
        self.counts[idx] += 1;
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Observation sum.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Bucket upper bounds.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts; last entry is +Inf bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Quantile estimate by linear interpolation within the bucket
    /// (the same estimator as Prometheus `histogram_quantile`).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let prev_cum = cum;
            cum += c;
            if (cum as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // +Inf bucket: report the lower bound.
                    return lo;
                };
                if c == 0 {
                    return hi;
                }
                let frac = (rank - prev_cum as f64) / c as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
        }
        *self.bounds.last().unwrap_or(&0.0)
    }

    /// Merge another histogram with identical bounds.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "merging mismatched histograms");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.observe(v);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_quantiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.observe(i as f64);
        }
        assert!((s.quantile(0.5) - 50.0).abs() <= 2.0);
        assert!((s.quantile(0.99) - 99.0).abs() <= 2.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 100.0);
    }

    #[test]
    fn summary_reservoir_bounded() {
        let mut s = Summary::with_capacity(64);
        for i in 0..10_000 {
            s.observe(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert!(s.reservoir.len() <= 64);
        // Quantile should still be roughly right.
        let med = s.quantile(0.5);
        assert!(med > 2_000.0 && med < 8_000.0, "median {med}");
    }

    #[test]
    fn summary_merge() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        a.observe(1.0);
        b.observe(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.max(), 3.0);
    }

    #[test]
    fn histogram_buckets_and_quantile() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 1.6, 3.0, 10.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.counts(), &[1, 2, 1, 1]);
        let med = h.quantile(0.5);
        assert!(med >= 1.0 && med <= 2.0, "median {med}");
    }

    #[test]
    fn histogram_empty_quantile_zero() {
        let h = Histogram::latency_seconds();
        assert_eq!(h.quantile(0.9), 0.0);
    }

    #[test]
    #[should_panic]
    fn histogram_rejects_unsorted_bounds() {
        let _ = Histogram::new(vec![2.0, 1.0]);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new(vec![1.0, 2.0]);
        let mut b = Histogram::new(vec![1.0, 2.0]);
        a.observe(0.5);
        b.observe(1.5);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.counts(), &[1, 1, 0]);
    }
}
