//! Bench harness (criterion is unavailable offline).
//!
//! [`Bencher`] runs warmup + timed iterations of a closure and reports
//! mean/p50/p99 wall time; [`Table`] renders aligned result tables matching
//! the paper's figures; [`Csv`] writes raw series for offline plotting.
//! All benches under `rust/benches/` are `harness = false` binaries built
//! on these.

use std::fmt::Write as _;
use std::time::Instant;

use super::stats::Summary;

/// True when the bench runs in CI smoke mode (`SUPERSONIC_SMOKE=1`):
/// benches shrink durations/iterations to a few seconds total so
/// `make bench-smoke` can execute every registered bench as a build
/// gate. Assertions stay on — smoke mode shortens, it does not skip.
pub fn smoke() -> bool {
    std::env::var("SUPERSONIC_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `full` normally, `short` under [`smoke`] — for sizing iteration
/// counts, client fleets, and run durations in one place.
pub fn smoke_scaled(full: usize, short: usize) -> usize {
    if smoke() {
        short
    } else {
        full
    }
}

/// Timed micro/meso-benchmark runner.
pub struct Bencher {
    warmup: usize,
    iters: usize,
}

/// One benchmark's timing results (seconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Bencher {
    /// Harness with `warmup` untimed and `iters` timed iterations.
    pub fn new(warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Bencher { warmup, iters }
    }

    /// Run `f` and collect timings.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.observe(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: s.mean(),
            p50_s: s.quantile(0.5),
            p99_s: s.quantile(0.99),
            min_s: s.min(),
            max_s: s.max(),
        }
    }
}

impl BenchResult {
    /// One formatted report line.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p99_s),
        )
    }
}

/// Human duration formatting (ns/us/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Aligned text table for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for i in 0..ncol {
                let _ = write!(out, "{:<w$}  ", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

/// CSV series writer for figure regeneration.
pub struct Csv {
    buf: String,
}

impl Csv {
    /// CSV with a header row.
    pub fn new(headers: &[&str]) -> Self {
        Csv { buf: format!("{}\n", headers.join(",")) }
    }

    /// Append one row of cells.
    pub fn row(&mut self, cells: &[String]) {
        self.buf.push_str(&cells.join(","));
        self.buf.push('\n');
    }

    /// Write to a file under `bench_results/`.
    pub fn save(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(name);
        std::fs::write(&path, &self.buf)?;
        Ok(path)
    }

    /// Raw CSV contents.
    pub fn contents(&self) -> &str {
        &self.buf
    }
}

/// Render an ASCII sparkline chart of a series (Grafana stand-in for
/// terminal output in examples/benches).
pub fn ascii_chart(title: &str, series: &[(f64, f64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return format!("{title}\n(empty series)\n");
    }
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, y) in series {
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let xmin = series.first().unwrap().0;
    let xmax = series.last().unwrap().0.max(xmin + 1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for &(x, y) in series {
        let col = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
        let row = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
        grid[height - 1 - row][col.min(width - 1)] = b'*';
    }
    let mut out = format!("{title}  [y: {ymin:.2} .. {ymax:.2}]\n");
    for line in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&line).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_times_work() {
        let b = Bencher::new(2, 10);
        let r = b.run("sleep", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(r.mean_s >= 0.001, "mean {}", r.mean_s);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn fmt_durations() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("us"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with("s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("longer-name"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_accumulates() {
        let mut c = Csv::new(&["t", "v"]);
        c.row(&["0".into(), "1.5".into()]);
        assert_eq!(c.contents(), "t,v\n0,1.5\n");
    }

    #[test]
    fn ascii_chart_renders() {
        let series: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, (i as f64).sin())).collect();
        let s = ascii_chart("sine", &series, 40, 8);
        assert!(s.contains('*'));
        assert!(s.lines().count() == 10);
    }
}
