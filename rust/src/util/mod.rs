//! Shared substrates: clock abstraction, thread pool, statistics, PRNG,
//! mini property-testing helper, logging and a bench harness.
//!
//! These exist because the reproduction environment is offline: the usual
//! crates (tokio, criterion, proptest, rand) are unavailable, so each is
//! implemented here as a small, tested substrate (see DESIGN.md
//! §Substitutions).

pub mod bench;
pub mod clock;
pub mod logging;
pub mod pool;
pub mod quick;
pub mod rng;
pub mod stats;

pub use clock::Clock;
pub use pool::ThreadPool;
pub use rng::Rng;
pub use stats::{Histogram, Summary};
