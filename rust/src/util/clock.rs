//! Clock abstraction: real, scaled, or simulated time.
//!
//! The paper's autoscaling experiments (Fig. 2/3) span tens of minutes of
//! wall-clock time. To reproduce their *dynamics* in a CI-sized budget every
//! component takes a [`Clock`], which can be:
//!
//! * [`Clock::real`] — plain wall clock (production mode),
//! * [`Clock::scaled`] — wall clock with time dilation: `scale = 10.0` makes
//!   one real second read as ten clock seconds, so a 25-minute experiment
//!   runs in 2.5 minutes while queueing dynamics (which depend on *ratios*
//!   of rates, not absolute durations) are preserved,
//! * [`Clock::simulated`] — fully virtual time advanced manually; used by
//!   deterministic unit tests of the autoscaler/orchestrator/batcher.
//!
//! Sleeps on a scaled clock divide the requested duration by the scale, so
//! a component that "waits 30s of cluster time" waits 3s of real time.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Monotonic nanosecond timestamp relative to the clock's epoch.
pub type Nanos = u64;

#[derive(Clone)]
enum Inner {
    Real {
        epoch: Instant,
        scale: f64,
    },
    Simulated {
        now: Arc<(Mutex<Nanos>, Condvar)>,
    },
}

/// A cloneable handle to a time source. Cheap to clone; clones share state.
#[derive(Clone)]
pub struct Clock {
    inner: Inner,
}

impl Clock {
    /// Wall-clock time, no dilation.
    pub fn real() -> Self {
        Clock {
            inner: Inner::Real { epoch: Instant::now(), scale: 1.0 },
        }
    }

    /// Wall-clock time dilated by `scale` (> 1 runs experiments faster).
    pub fn scaled(scale: f64) -> Self {
        assert!(scale > 0.0, "clock scale must be positive");
        Clock {
            inner: Inner::Real { epoch: Instant::now(), scale },
        }
    }

    /// Fully virtual clock starting at t=0; advance with [`Clock::advance`].
    pub fn simulated() -> Self {
        Clock {
            inner: Inner::Simulated {
                now: Arc::new((Mutex::new(0), Condvar::new())),
            },
        }
    }

    /// Current time in nanoseconds since the clock epoch.
    pub fn now(&self) -> Nanos {
        match &self.inner {
            Inner::Real { epoch, scale } => {
                let real = epoch.elapsed().as_nanos() as f64;
                (real * scale) as Nanos
            }
            Inner::Simulated { now } => *now.0.lock().unwrap(),
        }
    }

    /// Current time as a float number of seconds since the epoch.
    pub fn now_secs(&self) -> f64 {
        self.now() as f64 / 1e9
    }

    /// Sleep for `d` of *clock* time (real time `d / scale` on a scaled
    /// clock). On a simulated clock this blocks until another thread
    /// advances time past the deadline.
    pub fn sleep(&self, d: Duration) {
        match &self.inner {
            Inner::Real { scale, .. } => {
                let real = Duration::from_nanos((d.as_nanos() as f64 / scale) as u64);
                std::thread::sleep(real);
            }
            Inner::Simulated { now } => {
                let deadline = self.now() + d.as_nanos() as Nanos;
                let (lock, cvar) = &**now;
                let mut t = lock.lock().unwrap();
                while *t < deadline {
                    let (nt, timeout) = cvar
                        .wait_timeout(t, Duration::from_millis(50))
                        .unwrap();
                    t = nt;
                    // Defensive: if nobody is advancing the clock, a
                    // simulated sleep would deadlock. Tests advance time
                    // from a driver thread; the timeout re-checks.
                    if timeout.timed_out() && *t >= deadline {
                        break;
                    }
                }
            }
        }
    }

    /// Advance a simulated clock by `d`, waking sleepers.
    /// Panics if called on a real clock.
    pub fn advance(&self, d: Duration) {
        match &self.inner {
            Inner::Simulated { now } => {
                let (lock, cvar) = &**now;
                let mut t = lock.lock().unwrap();
                *t += d.as_nanos() as Nanos;
                cvar.notify_all();
            }
            _ => panic!("advance() is only valid on a simulated clock"),
        }
    }

    /// True if this is a simulated clock (used by components that spawn
    /// polling threads to pick a strategy).
    pub fn is_simulated(&self) -> bool {
        matches!(self.inner, Inner::Simulated { .. })
    }

    /// Duration elapsed since an earlier `now()` reading.
    pub fn since(&self, earlier: Nanos) -> Duration {
        Duration::from_nanos(self.now().saturating_sub(earlier))
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::real()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = Clock::real();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        let b = c.now();
        assert!(b > a);
    }

    #[test]
    fn scaled_clock_dilates() {
        let c = Clock::scaled(100.0);
        let a = c.now();
        std::thread::sleep(Duration::from_millis(10));
        let b = c.now();
        // 10ms real should read as ~1s of clock time; allow slack.
        assert!(c.since(a).as_millis() >= 500, "elapsed {:?}", b - a);
    }

    #[test]
    fn scaled_sleep_is_shorter() {
        let c = Clock::scaled(50.0);
        let t0 = Instant::now();
        c.sleep(Duration::from_millis(500)); // should take ~10ms real
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn simulated_clock_advances() {
        let c = Clock::simulated();
        assert_eq!(c.now(), 0);
        c.advance(Duration::from_secs(5));
        assert_eq!(c.now_secs(), 5.0);
    }

    #[test]
    fn simulated_sleep_wakes_on_advance() {
        let c = Clock::simulated();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_secs(10));
            c2.now()
        });
        std::thread::sleep(Duration::from_millis(20));
        c.advance(Duration::from_secs(10));
        let woke_at = h.join().unwrap();
        assert!(woke_at >= Duration::from_secs(10).as_nanos() as u64);
    }

    #[test]
    fn clones_share_simulated_state() {
        let c = Clock::simulated();
        let c2 = c.clone();
        c.advance(Duration::from_secs(1));
        assert_eq!(c2.now(), c.now());
    }
}
