//! Minimal `log` backend writing leveled, timestamped lines to stderr.
//!
//! `env_logger` is unavailable offline; this sink honours the
//! `SUPERSONIC_LOG` env var (error|warn|info|debug|trace, default info).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;
static INITIALIZED: AtomicBool = AtomicBool::new(false);

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default();
        let secs = now.as_secs();
        let millis = now.subsec_millis();
        let level = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "{secs}.{millis:03} {level} [{}] {}",
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level comes from `SUPERSONIC_LOG`.
pub fn init() {
    if INITIALIZED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("SUPERSONIC_LOG")
        .unwrap_or_default()
        .to_lowercase()
        .as_str()
    {
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        "off" => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
