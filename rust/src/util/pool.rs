//! Fixed-size thread pool with graceful shutdown.
//!
//! tokio is unavailable offline, so concurrency in the coordinator is
//! thread-based: the RPC server runs a connection-per-thread accept loop on
//! this pool, and inference instances own dedicated executor threads. The
//! pool is deliberately simple — bounded queue, panic isolation, join on
//! drop — because its behaviour must be predictable under the benches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` worker threads named `<name>-<i>`.
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let active = Arc::clone(&active);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match msg {
                        Ok(Message::Run(job)) => {
                            active.fetch_add(1, Ordering::SeqCst);
                            // Panic isolation: a panicking job must not take
                            // the worker down.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            active.fetch_sub(1, Ordering::SeqCst);
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawning pool worker");
            workers.push(handle);
        }
        ThreadPool { tx, workers, active }
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .send(Message::Run(Box::new(job)))
            .expect("thread pool has shut down");
    }

    /// Jobs currently executing (approximate).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;
    use std::time::Duration;

    #[test]
    fn runs_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(1, "panic");
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicU32::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.store(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallelism_actually_parallel() {
        let pool = ThreadPool::new(4, "par");
        let start = std::time::Instant::now();
        let done = Arc::new(AtomicU32::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                std::thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        // 4 x 50ms serial would be 200ms; parallel should be well under.
        assert!(start.elapsed() < Duration::from_millis(150));
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }
}
