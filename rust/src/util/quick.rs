//! Mini property-based testing helper (proptest is unavailable offline).
//!
//! [`check`] runs a property over `iters` randomly generated cases; on
//! failure it performs greedy shrinking via the case's [`Shrink`] impl and
//! panics with the minimal failing case and the seed needed to replay it.
//!
//! ```
//! use supersonic::util::quick::{check, Gen};
//! check("sort is idempotent", 200, |g: &mut Gen| {
//!     let mut xs = g.vec_u64(0..=100, 0..=20);
//!     xs.sort();
//!     let once = xs.clone();
//!     xs.sort();
//!     assert_eq!(once, xs);
//! });
//! ```

use super::rng::Rng;

/// Random case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Log of generated scalars, used for replay-based shrinking.
    pub(crate) size: usize,
}

impl Gen {
    fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::seeded(seed), size }
    }

    /// Current "size" hint (shrinks toward 0 on failure).
    pub fn size(&self) -> usize {
        self.size
    }

    /// u64 in the inclusive range.
    pub fn u64(&mut self, range: std::ops::RangeInclusive<u64>) -> u64 {
        self.rng.range_u64(*range.start(), *range.end())
    }

    /// usize in the inclusive range, additionally capped by the size hint.
    pub fn usize(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let hi = (*range.end()).min(range.start() + self.size);
        self.rng.range_u64(*range.start() as u64, hi as u64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of u64s with length drawn from `len` (capped by size hint).
    pub fn vec_u64(
        &mut self,
        range: std::ops::RangeInclusive<u64>,
        len: std::ops::RangeInclusive<usize>,
    ) -> Vec<u64> {
        let n = self.usize(len);
        (0..n).map(|_| self.u64(range.clone())).collect()
    }

    /// Vector of f64s.
    pub fn vec_f64(
        &mut self,
        lo: f64,
        hi: f64,
        len: std::ops::RangeInclusive<usize>,
    ) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Pick one of the provided options.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }
}

/// Run `prop` over `iters` random cases. Panics (with seed and case number)
/// on the first failure after shrinking the size hint.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, iters: u64, prop: F) {
    let base_seed = match std::env::var("QUICK_SEED") {
        Ok(s) => s.parse().unwrap_or(0xC0FFEE),
        Err(_) => 0xC0FFEE,
    };
    for i in 0..iters {
        let seed = base_seed ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let size = 4 + (i as usize % 64) * 4; // grow cases over iterations
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(payload) = result {
            // Greedy shrink: retry the same seed with smaller size hints.
            let mut min_size = size;
            for s in (0..size).rev() {
                let shrunk = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                });
                if shrunk.is_err() {
                    min_size = s;
                } else {
                    break;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}, \
                 shrunk size {min_size}): {msg}\n\
                 replay with QUICK_SEED={base_seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 100, |g| {
            let a = g.u64(0..=1000);
            let b = g.u64(0..=1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 10, |g| {
            let v = g.u64(0..=10);
            assert!(v > 100, "generated {v}");
        });
    }

    #[test]
    fn sizes_grow() {
        // vec length is capped by the size hint, which starts small.
        check("bounded lengths", 50, |g| {
            let xs = g.vec_u64(0..=10, 0..=1000);
            assert!(xs.len() <= g.size() + 1);
        });
    }
}
