//! Small deterministic PRNG (xoshiro256**) used by the workload generator,
//! property tests and failure injection. `rand` is unavailable offline; this
//! is the standard xoshiro256** algorithm, seeded via splitmix64.

/// Deterministic, seedable PRNG. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for our uses; modulo bias is
        // negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64(); // full range: modulus would overflow
        }
        lo + self.next_u64() % (span + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed sample with the given mean (for Poisson
    /// arrival processes in the workload generator).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller (used for synthetic tensor payloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Rng::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::seeded(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::seeded(6);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
