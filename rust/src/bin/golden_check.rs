// Numerics check: execute every (model, batch) artifact against its golden.
fn main() -> anyhow::Result<()> {
    let rt = supersonic::runtime::PjrtRuntime::cpu()?;
    let mut bad = 0;
    for model in ["particlenet", "icecube_cnn", "cms_transformer"] {
        let dir = std::path::Path::new("artifacts").join(model);
        let set = supersonic::runtime::EngineSet::load(&rt, &dir, model)?;
        for bs in set.batch_sizes() {
            let g = supersonic::runtime::golden::load(&dir.join(format!("golden.b{bs}.txt")))?;
            let eng = set.engine_exact(bs).unwrap();
            let t0 = std::time::Instant::now();
            let out = eng.execute(&g.input)?;
            let dt = t0.elapsed();
            let diff = out.max_abs_diff(&g.output)?;
            let ok = diff < 1e-3;
            if !ok { bad += 1; }
            println!("{model} b{bs}: max_abs_diff={diff:.3e} exec={dt:?} {}", if ok {"OK"} else {"FAIL"});
        }
    }
    if bad > 0 { anyhow::bail!("{bad} golden mismatches"); }
    println!("ALL GOLDENS OK");
    Ok(())
}
