//! SuperSONIC — cloud-native ML inference-as-a-service, reproduced.
//!
//! This crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **Layer 1** — Pallas kernels (build-time Python, `python/compile/kernels/`):
//!   the EdgeConv hot-spot of the ParticleNet GNN, lowered in interpret mode.
//! * **Layer 2** — JAX models (build-time Python, `python/compile/`): ParticleNet-like
//!   GNN, a CNN (IceCube/LIGO-style) and a small transformer (CMS-style), AOT-lowered
//!   to HLO text in `artifacts/`.
//! * **Layer 3** — this crate: the SuperSONIC server infrastructure. It loads the
//!   AOT artifacts through PJRT ([`runtime`]) and implements every server-side
//!   component the paper describes: the Envoy-style gateway ([`gateway`]), the
//!   Triton-style inference server ([`server`]), the Kubernetes-style cluster
//!   orchestrator ([`orchestrator`]), the KEDA-style autoscaler ([`autoscaler`]),
//!   the Prometheus-style metrics pipeline ([`metrics`]), OpenTelemetry-style
//!   tracing ([`telemetry`]) and the perf_analyzer-style load generator
//!   ([`workload`]).
//!
//! On top of the base paper stack sits the **modelmesh** ([`modelmesh`]):
//! dynamic model placement and model-aware routing, reproducing the
//! SuperSONIC dynamic-model-loading follow-up. Instances advertise a
//! per-pod serving set (the pod-label mechanism), the gateway routes each
//! request through a per-model load balancer whose address pool follows
//! those labels, and a placement controller — driven by the cluster
//! reconcile loop — loads/unloads models per instance from GPU-memory
//! budgets and per-model demand. The `model_placement` config section
//! selects `static` (fixed placement) or `dynamic` (demand-driven)
//! policies; with the default unlimited budget the deployment degenerates
//! to the base all-models-everywhere setup.
//!
//! **Multi-backend engines** ([`engine`]) make the runtime pluggable:
//! a [`Backend`](engine::Backend) trait with the PJRT runtime
//! ([`engine::PjrtBackend`]) and a deterministic simulated CPU-capable
//! second runtime ([`engine::OnnxSimBackend`]) behind it. Pods advertise
//! a backend set derived from their accelerator class (`gpu` vs `cpu` —
//! `engines.cpu_replicas` boots a CPU fleet next to the GPUs), each
//! model resolves a backend preference list (`server.models[].backends`),
//! and placement/routing only ever land a model where a compatible
//! backend exists, falling back to a later-preference backend when the
//! preferred one has no capacity.
//!
//! **Multi-site federation** ([`federation`]) lifts the whole control
//! plane one level up: the `federation` config section boots N sites —
//! each with its own cluster, mesh router, placement controller and
//! per-model scaler — behind one federation-tier gateway that routes
//! every request to the cheapest site (by WAN penalty) with warm
//! capacity, spills over when a site saturates, and repatriates when it
//! recovers. A global rebalancer shifts per-model pod budget between
//! sites from the site-labeled demand signal and raises a `site_outage`
//! alert when a whole site drains.
//!
//! **Per-model autoscaling** (`autoscaler.per_model`) closes the loop
//! between the two: instead of one global replica count, the autoscaler
//! runs one scaling loop per served model, fed by the placement
//! controller's demand signal. Hot models gain pods that boot advertising
//! only that model (boot profiles), scale-down prefers victims whose
//! serving sets are redundant, and `autoscaler.max_replicas` remains the
//! total pod budget shared across models. See `docs/ARCHITECTURE.md` for
//! the full control-plane walkthrough and `docs/CONFIG.md` for the
//! config reference.
//!
//! Python never runs on the request path: `make artifacts` is the only step that
//! invokes it, and the resulting binary is self-contained. Real PJRT
//! execution requires the optional `pjrt` cargo feature (the `xla` crate);
//! without it, simulated execution covers the full control plane.

pub mod autoscaler;
pub mod config;
pub mod deployment;
pub mod engine;
pub mod experiments;
pub mod federation;
pub mod gateway;
pub mod metrics;
pub mod modelmesh;
pub mod orchestrator;
pub mod rpc;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod util;
pub mod workload;
