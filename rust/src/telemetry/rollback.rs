//! Canary auto-rollback — the promotion safety net of the model-version
//! lifecycle. While a canary split is live, the gateway stamps
//! per-(model, version) requests/errors/latency; this evaluator compares
//! the canary arm against the incumbent arm over the same fast/slow
//! burn-rate windows the SLO engine uses ([`super::slo`]) and, when the
//! canary is worse on both windows — error rate above the incumbent's by
//! more than `observability.rollback_error_margin`, or windowed p99
//! above `rollback_latency_factor` × the incumbent's — it triggers the
//! deployment's rollback action (tear down the split, swap placement
//! back), counts `model_version_rollback_total{model=...}`, raises the
//! `canary_auto_rollback` alert, and appends a structured alert-log
//! entry. One rollback per model per canary: after firing, the model is
//! ignored until a new split is installed.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::schema::ObservabilityConfig;
use crate::metrics::registry::{labels, Registry};
use crate::metrics::store::MetricStore;
use crate::server::split_version;
use crate::telemetry::flight::{DecisionEvent, LoopTicker, RecorderHandle};
use crate::telemetry::slo::{AlertEvent, AlertKind, ALERT_GAUGE};
use crate::util::clock::Clock;

/// Alert name raised when an automatic rollback fires.
pub const ROLLBACK_ALERT: &str = "canary_auto_rollback";

/// Counter of automatic rollbacks, labeled by base model name.
pub const ROLLBACK_COUNTER: &str = "model_version_rollback_total";

/// Per-(model, version) counter of infer responses routed by version.
pub const VERSION_REQUESTS_COUNTER: &str = "model_version_requests_total";

/// Per-(model, version) counter of non-OK infer responses.
pub const VERSION_ERRORS_COUNTER: &str = "model_version_errors_total";

/// Per-(model, version) histogram of OK request latency.
pub const VERSION_LATENCY_HIST: &str = "gateway_model_version_latency_seconds";

/// Per-(model, version) gauge of warm replicas, set by the placement
/// controller on every reconcile.
pub const VERSION_REPLICAS_GAUGE: &str = "model_version_replicas";

/// Every version-lifecycle metric name, for the docs-sync gate.
pub const VERSION_METRICS: &[&str] = &[
    VERSION_REQUESTS_COUNTER,
    VERSION_ERRORS_COUNTER,
    VERSION_LATENCY_HIST,
    VERSION_REPLICAS_GAUGE,
    ROLLBACK_COUNTER,
];

/// One live canary the evaluator watches: the base (client-facing) name
/// plus the two concrete versioned names under comparison.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CanarySnapshot {
    pub base: String,
    /// Versioned incumbent name (e.g. `pn@v1`).
    pub incumbent: String,
    /// Versioned canary name (e.g. `pn@v2`).
    pub canary: String,
}

/// Reads the live canary set (the deployment points this at
/// `ModelRouter::canary_of` so splits installed or cleared at runtime
/// are picked up on the next evaluation).
pub type CanaryProbe = Box<dyn Fn() -> Vec<CanarySnapshot> + Send + Sync>;

/// Invoked once per fired rollback (tear down the split, restore
/// placement). Runs on the evaluator thread.
pub type RollbackAction = Box<dyn Fn(&CanarySnapshot) + Send + Sync>;

/// The canary-vs-incumbent evaluator. Create once, call
/// [`eval_once`](Self::eval_once) on a cadence (or let [`RollbackTask`]
/// drive it on the clock).
pub struct RollbackEngine {
    cfg: ObservabilityConfig,
    registry: Registry,
    store: MetricStore,
    clock: Clock,
    probe: CanaryProbe,
    action: RollbackAction,
    /// Base names whose rollback already fired — one shot per split.
    done: Mutex<BTreeSet<String>>,
    events: Mutex<Vec<AlertEvent>>,
    recorder: RecorderHandle,
}

/// One arm's windowed deltas: requests, errors, and per-bucket latency
/// counts over the trailing window.
struct ArmWindow {
    requests: f64,
    errors: f64,
    lat_deltas: Vec<f64>,
}

impl RollbackEngine {
    /// Engine over the shared registry (gateway version feed) and store
    /// (windowing), with a live-canary probe and a rollback action.
    pub fn new(
        cfg: ObservabilityConfig,
        registry: Registry,
        store: MetricStore,
        clock: Clock,
        probe: CanaryProbe,
        action: RollbackAction,
    ) -> Self {
        RollbackEngine {
            cfg,
            registry,
            store,
            clock,
            probe,
            action,
            done: Mutex::new(BTreeSet::new()),
            events: Mutex::new(Vec::new()),
            recorder: RecorderHandle::default(),
        }
    }

    /// The flight-recorder slot rollback firings land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// Evaluate every live canary once at the current clock time.
    pub fn eval_once(&self) {
        let now = self.clock.now_secs();
        for snap in (self.probe)() {
            if self.done.lock().unwrap().contains(&snap.base) {
                continue;
            }
            // Publish cumulative per-arm series so window deltas work
            // (same pattern as the SLO engine's slo_*_total feed).
            self.push_arm(&snap.base, &snap.incumbent, now);
            self.push_arm(&snap.base, &snap.canary, now);

            let fast = self.window(&snap, now, self.cfg.slo_fast_window);
            let slow = self.window(&snap, now, self.cfg.slo_slow_window);
            let (Some(fast), Some(slow)) = (fast, slow) else {
                continue;
            };
            if fast.0 && slow.0 {
                self.fire(&snap, now, fast.1, slow.1);
            }
        }
    }

    /// Version label for a versioned name (`pn@v2` -> `v2`).
    fn version_label(name: &str) -> Option<String> {
        split_version(name).1.map(|v| format!("v{v}"))
    }

    /// Push one arm's cumulative counters + latency buckets into the
    /// store at `now`.
    fn push_arm(&self, base: &str, arm: &str, now: f64) {
        let Some(ver) = Self::version_label(arm) else {
            return;
        };
        let l = labels(&[("model", base), ("version", &ver)]);
        let requests = self.registry.counter(VERSION_REQUESTS_COUNTER, &l).get() as f64;
        let errors = self.registry.counter(VERSION_ERRORS_COUNTER, &l).get() as f64;
        self.store
            .push(&format!("rollback_requests_total{{model=\"{base}\",version=\"{ver}\"}}"), now, requests);
        self.store
            .push(&format!("rollback_errors_total{{model=\"{base}\",version=\"{ver}\"}}"), now, errors);
        let h = self.registry.histogram(VERSION_LATENCY_HIST, &l).snapshot();
        for (i, &c) in h.counts().iter().enumerate() {
            self.store.push(
                &format!(
                    "rollback_lat_bucket{{model=\"{base}\",version=\"{ver}\",bucket=\"{i}\"}}"
                ),
                now,
                c as f64,
            );
        }
    }

    /// Last-minus-first delta of a cumulative series over the trailing
    /// window; `None` until two points exist.
    fn delta(&self, series: &str, now: f64, window: Duration) -> Option<f64> {
        let pts = self.store.range(series, now - window.as_secs_f64(), now);
        if pts.len() < 2 {
            return None;
        }
        Some(pts[pts.len() - 1].1 - pts[0].1)
    }

    /// One arm's windowed deltas; `None` until the window holds two
    /// samples of the request series.
    fn arm_window(&self, base: &str, arm: &str, now: f64, w: Duration) -> Option<ArmWindow> {
        let ver = Self::version_label(arm)?;
        let requests = self.delta(
            &format!("rollback_requests_total{{model=\"{base}\",version=\"{ver}\"}}"),
            now,
            w,
        )?;
        let errors = self
            .delta(
                &format!("rollback_errors_total{{model=\"{base}\",version=\"{ver}\"}}"),
                now,
                w,
            )
            .unwrap_or(0.0);
        let l = labels(&[("model", base), ("version", &ver)]);
        let nbuckets = self.registry.histogram(VERSION_LATENCY_HIST, &l).snapshot().counts().len();
        let lat_deltas = (0..nbuckets)
            .map(|i| {
                self.delta(
                    &format!(
                        "rollback_lat_bucket{{model=\"{base}\",version=\"{ver}\",bucket=\"{i}\"}}"
                    ),
                    now,
                    w,
                )
                .unwrap_or(0.0)
                .max(0.0)
            })
            .collect();
        Some(ArmWindow { requests, errors, lat_deltas })
    }

    /// Judge one window: `Some((breach, severity))` once both arms have
    /// enough windowed traffic to compare, `None` otherwise. `severity`
    /// is the worse of the two normalized excesses (1.0 = right at the
    /// rollback threshold), recorded on the alert event.
    fn window(&self, snap: &CanarySnapshot, now: f64, w: Duration) -> Option<(bool, f64)> {
        let inc = self.arm_window(&snap.base, &snap.incumbent, now, w)?;
        let can = self.arm_window(&snap.base, &snap.canary, now, w)?;
        let min = self.cfg.rollback_min_requests as f64;
        if inc.requests < min || can.requests < min {
            return None;
        }
        let inc_err = inc.errors.max(0.0) / inc.requests;
        let can_err = can.errors.max(0.0) / can.requests;
        let margin = self.cfg.rollback_error_margin.max(1e-9);
        let err_severity = (can_err - inc_err) / margin;

        // Latency is compared only when both arms served OK requests in
        // the window (the histogram counts OK responses only); an
        // all-error canary is caught by the error comparison.
        let bounds = self
            .registry
            .histogram(
                VERSION_LATENCY_HIST,
                &labels(&[
                    ("model", &snap.base),
                    ("version", &Self::version_label(&snap.incumbent).unwrap_or_default()),
                ]),
            )
            .snapshot()
            .bounds()
            .to_vec();
        let inc_total: f64 = inc.lat_deltas.iter().sum();
        let can_total: f64 = can.lat_deltas.iter().sum();
        let lat_severity = if inc_total >= 1.0 && can_total >= 1.0 {
            let inc_p99 = quantile_from_deltas(&bounds, &inc.lat_deltas, 0.99);
            let can_p99 = quantile_from_deltas(&bounds, &can.lat_deltas, 0.99);
            if inc_p99 > 0.0 {
                (can_p99 / inc_p99) / self.cfg.rollback_latency_factor
            } else {
                0.0
            }
        } else {
            0.0
        };
        let severity = err_severity.max(lat_severity);
        Some((severity > 1.0, severity))
    }

    /// Fire the rollback for one canary: run the action, export the
    /// alert + counter, log the event, and mark the base done.
    fn fire(&self, snap: &CanarySnapshot, now: f64, fast: f64, slow: f64) {
        (self.action)(snap);
        self.registry
            .counter(ROLLBACK_COUNTER, &labels(&[("model", &snap.base)]))
            .inc();
        self.registry
            .gauge(
                ALERT_GAUGE,
                &labels(&[("alert", ROLLBACK_ALERT), ("model", &snap.base)]),
            )
            .set(1.0);
        self.events.lock().unwrap().push(AlertEvent {
            at: now,
            model: snap.base.clone(),
            alert: ROLLBACK_ALERT,
            kind: AlertKind::Fired,
            burn_fast: fast,
            burn_slow: slow,
        });
        self.recorder.record(
            DecisionEvent::new("rollback", "rollback")
                .model(&snap.base)
                .version(&snap.canary)
                .input("severity_fast", fast)
                .input("severity_slow", slow)
                .action(format!(
                    "rolled '{}' back to '{}'",
                    snap.canary, snap.incumbent
                )),
        );
        self.done.lock().unwrap().insert(snap.base.clone());
    }

    /// Has a rollback fired for `base` (since the last re-arm)?
    pub fn rolled_back(&self, base: &str) -> bool {
        self.done.lock().unwrap().contains(base)
    }

    /// Re-arm `base` after a new canary split is installed, so the next
    /// bad version can roll back too.
    pub fn rearm(&self, base: &str) {
        self.done.lock().unwrap().remove(base);
        self.registry
            .gauge(ALERT_GAUGE, &labels(&[("alert", ROLLBACK_ALERT), ("model", base)]))
            .set(0.0);
    }

    /// Structured alert log (rollbacks in evaluation order).
    pub fn events(&self) -> Vec<AlertEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Rendered alert log, one line per rollback.
    pub fn render_log(&self) -> String {
        self.events
            .lock()
            .unwrap()
            .iter()
            .map(|e| e.render())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Quantile estimate over a windowed (delta) bucket histogram, linearly
/// interpolating within the straddling bucket — `histogram_quantile`
/// over `increase(bucket[w])`. `deltas` has one entry per bucket, the
/// last being +Inf; a quantile landing there answers the highest finite
/// bound (the estimator's conventional clamp).
fn quantile_from_deltas(bounds: &[f64], deltas: &[f64], q: f64) -> f64 {
    let total: f64 = deltas.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let target = q.clamp(0.0, 1.0) * total;
    let mut cum = 0.0;
    for (i, &d) in deltas.iter().enumerate() {
        if cum + d >= target && d > 0.0 {
            if i >= bounds.len() {
                return bounds.last().copied().unwrap_or(0.0);
            }
            let lo = if i == 0 { 0.0 } else { bounds[i - 1] };
            let hi = bounds[i];
            return lo + (hi - lo) * ((target - cum) / d).clamp(0.0, 1.0);
        }
        cum += d;
    }
    bounds.last().copied().unwrap_or(0.0)
}

/// Background evaluation loop on the shared clock (Scraper-style:
/// dropping the task stops and joins the thread).
pub struct RollbackTask {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RollbackTask {
    /// Evaluate `engine` every `interval` of clock time.
    pub fn start(engine: Arc<RollbackEngine>, clock: Clock, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let ticker = LoopTicker::new(&engine.registry, clock.clone(), "rollback");
        let handle = std::thread::Builder::new()
            .name("rollback-engine".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    ticker.tick(|| engine.eval_once());
                    clock.sleep(interval);
                }
            })
            .expect("spawning rollback engine");
        RollbackTask { stop, handle: Some(handle) }
    }
}

impl Drop for RollbackTask {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn test_cfg() -> ObservabilityConfig {
        ObservabilityConfig {
            slo_fast_window: Duration::from_secs(60),
            slo_slow_window: Duration::from_secs(300),
            rollback_latency_factor: 2.0,
            rollback_error_margin: 0.05,
            rollback_min_requests: 10,
            ..ObservabilityConfig::default()
        }
    }

    fn engine(
        cfg: ObservabilityConfig,
    ) -> (Arc<RollbackEngine>, Registry, Clock, Arc<AtomicUsize>) {
        let registry = Registry::new();
        let store = MetricStore::new(Duration::from_secs(3600));
        let clock = Clock::simulated();
        let fired = Arc::new(AtomicUsize::new(0));
        let fired2 = Arc::clone(&fired);
        let probe: CanaryProbe = Box::new(|| {
            vec![CanarySnapshot {
                base: "pn".into(),
                incumbent: "pn@v1".into(),
                canary: "pn@v2".into(),
            }]
        });
        let action: RollbackAction = Box::new(move |snap| {
            assert_eq!(snap.canary, "pn@v2");
            fired2.fetch_add(1, Ordering::SeqCst);
        });
        let e = Arc::new(RollbackEngine::new(
            cfg,
            registry.clone(),
            store,
            clock.clone(),
            probe,
            action,
        ));
        (e, registry, clock, fired)
    }

    fn feed(registry: &Registry, ver: &str, n: u64, errs: u64, latency: f64) {
        let l = labels(&[("model", "pn"), ("version", ver)]);
        registry.counter(VERSION_REQUESTS_COUNTER, &l).add(n);
        registry.counter(VERSION_ERRORS_COUNTER, &l).add(errs);
        let h = registry.histogram(VERSION_LATENCY_HIST, &l);
        for _ in 0..(n - errs) {
            h.observe(latency);
        }
    }

    #[test]
    fn quantile_from_deltas_interpolates() {
        let bounds = vec![0.1, 0.2, 0.4];
        // 10 in (0, 0.1], 10 in (0.2, 0.4], none beyond.
        let deltas = vec![10.0, 0.0, 10.0, 0.0];
        // Median sits exactly at the first bound.
        assert!((quantile_from_deltas(&bounds, &deltas, 0.5) - 0.1).abs() < 1e-9);
        // 75th percentile: halfway through the (0.2, 0.4] bucket.
        assert!((quantile_from_deltas(&bounds, &deltas, 0.75) - 0.3).abs() < 1e-9);
        // All mass in +Inf clamps to the highest finite bound.
        assert!((quantile_from_deltas(&bounds, &[0.0, 0.0, 0.0, 5.0], 0.99) - 0.4).abs() < 1e-9);
        assert_eq!(quantile_from_deltas(&bounds, &[0.0; 4], 0.99), 0.0);
    }

    #[test]
    fn slow_canary_rolls_back_once() {
        let (e, registry, clock, fired) = engine(test_cfg());
        e.eval_once(); // baseline points
        // Incumbent fast, canary ~20x slower: p99 ratio far above the
        // 2x factor on both windows.
        feed(&registry, "v1", 100, 0, 0.005);
        feed(&registry, "v2", 40, 0, 0.1);
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert_eq!(fired.load(Ordering::SeqCst), 1, "rollback must fire");
        assert!(e.rolled_back("pn"));
        assert_eq!(
            registry.counter(ROLLBACK_COUNTER, &labels(&[("model", "pn")])).get(),
            1
        );
        assert!(
            (registry
                .gauge(ALERT_GAUGE, &labels(&[("alert", ROLLBACK_ALERT), ("model", "pn")]))
                .get()
                - 1.0)
                .abs()
                < 1e-9
        );
        assert!(e.render_log().contains(ROLLBACK_ALERT));
        // One-shot: further evaluations must not fire again.
        feed(&registry, "v1", 100, 0, 0.005);
        feed(&registry, "v2", 40, 0, 0.1);
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // Re-arming (new split) makes it eligible again.
        e.rearm("pn");
        assert!(!e.rolled_back("pn"));
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert_eq!(fired.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn erroring_canary_rolls_back() {
        let (e, registry, clock, fired) = engine(test_cfg());
        e.eval_once();
        // Same latency both arms, but the canary errors 50% against a
        // 5% margin.
        feed(&registry, "v1", 100, 0, 0.005);
        feed(&registry, "v2", 40, 20, 0.005);
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        let ev = &e.events()[0];
        assert_eq!(ev.alert, ROLLBACK_ALERT);
        assert_eq!(ev.kind, AlertKind::Fired);
        assert!(ev.burn_fast > 1.0);
    }

    #[test]
    fn healthy_canary_left_alone() {
        let (e, registry, clock, fired) = engine(test_cfg());
        e.eval_once();
        for _ in 0..5 {
            feed(&registry, "v1", 100, 1, 0.005);
            feed(&registry, "v2", 40, 0, 0.006);
            clock.advance(Duration::from_secs(10));
            e.eval_once();
        }
        assert_eq!(fired.load(Ordering::SeqCst), 0, "healthy canary must survive");
        assert!(e.events().is_empty());
    }

    #[test]
    fn min_requests_guards_noise() {
        let (e, registry, clock, fired) = engine(test_cfg());
        e.eval_once();
        // Canary horribly slow but only 3 windowed requests (< 10 min):
        // too little evidence to roll back.
        feed(&registry, "v1", 100, 0, 0.005);
        feed(&registry, "v2", 3, 0, 1.0);
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert_eq!(fired.load(Ordering::SeqCst), 0);
    }
}
