//! Span tracing — the OpenTelemetry/Tempo analogue (§2.3).
//!
//! The paper uses tracing for "a more detailed analysis of inference
//! request flows and performance bottlenecks". Here a [`Tracer`] collects
//! [`Span`]s (named, timed segments tied to a trace id) into a bounded
//! in-memory buffer; [`TraceView`] reassembles a request's spans into the
//! per-source latency breakdown (client -> gateway -> queue -> compute)
//! that the §2.3 "breakdown of total request latency by source" metric
//! reports.
//!
//! Trace context is propagated on the wire (`InferRequest::trace_id` plus
//! a head-sampling bit), so one trace id follows a request across gateway
//! admit / rate-limit / route, per-(model, priority) queue wait, batch
//! assembly, backend execution and every retry hop. A [`StageRecorder`]
//! folds finished traces into `request_stage_seconds{stage=...}`
//! histograms, and [`slo`] evaluates burn-rate alerts over the resulting
//! series.

pub mod flight;
pub mod rollback;
pub mod slo;

use std::collections::{HashMap, HashSet, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::registry::{labels, Counter, HistogramHandle, Registry};
use crate::util::clock::Clock;

/// Name of the root (end-to-end) span recorded by the gateway.
pub const ROOT_SPAN: &str = "gateway";

/// Every stage label emitted on `request_stage_seconds{stage=...}`.
///
/// `admit`/`ratelimit`/`route`/`retry` are gateway-side, `queue`/`batch`/
/// `compute` are server-side, `wan` is the cross-site hop a federated
/// request pays when served away from the gateway site (its histogram is
/// additionally labeled by serving site), and `other` is the residual of
/// the root span not covered by any named stage (channel hand-off, reply
/// delivery).
pub const STAGES: &[&str] = &[
    "admit", "ratelimit", "route", "retry", "wan", "queue", "batch", "compute", "other",
];

/// Series name for the per-stage latency breakdown histograms.
pub const STAGE_HISTOGRAM: &str = "request_stage_seconds";

/// Counter of spans evicted from the trace buffer before being read,
/// labeled by the site that recorded the evicted span (`site="local"`
/// outside federation) — N sites share one buffer and one registry, so
/// an unlabeled counter would let a single noisy site mask the others.
pub const SPANS_DROPPED_COUNTER: &str = "trace_spans_dropped_total";

/// Counter of finished traces skipped by the breakdown because part of
/// their span set had already been evicted, labeled by the site that
/// served the request (`site="local"` outside federation).
pub const PARTIAL_TRACES_COUNTER: &str = "trace_partial_total";

/// Site label attributed to spans and traces outside federation.
pub const LOCAL_SITE: &str = "local";

/// One finished span.
#[derive(Clone, Debug)]
pub struct Span {
    pub trace_id: u64,
    pub name: String,
    /// Clock-seconds start/end.
    pub start: f64,
    pub end: f64,
}

impl Span {
    /// Span duration in seconds.
    pub fn duration(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }
}

/// In-flight span guard: records the span on drop (RAII).
pub struct SpanGuard {
    tracer: Tracer,
    trace_id: u64,
    name: String,
    start: f64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = self.tracer.clock.now_secs();
        self.tracer.record(Span {
            trace_id: self.trace_id,
            name: std::mem::take(&mut self.name),
            start: self.start,
            end,
        });
    }
}

/// Spans indexed by trace id plus an insertion-order ring for eviction.
/// Keeping the index keyed by trace makes `trace()` O(spans of that
/// trace) instead of a scan of the whole buffer — the gateway reads a
/// trace back on every sampled request, so this is on the hot path.
#[derive(Default)]
struct Buffer {
    /// (trace id, recording site) of each retained span, oldest first
    /// (eviction order); the site attributes drops to their origin.
    ring: VecDeque<(u64, Arc<str>)>,
    /// Per-trace spans in insertion order.
    traces: HashMap<u64, Vec<Span>>,
    /// Spans evicted since construction.
    dropped: u64,
    /// Trace ids that lost at least one span (bounded; see overflow).
    dropped_traces: HashSet<u64>,
    /// Set when `dropped_traces` itself overflowed: from then on every
    /// trace is conservatively considered partial.
    dropped_overflow: bool,
}

/// Bound on the evicted-trace-id set before we fall back to marking
/// every trace partial.
const DROPPED_TRACES_CAP: usize = 4096;

/// Registry binding for drop accounting: one counter per recording
/// site, created lazily as sites record spans (shared across clones so
/// late binding reaches every handle).
#[derive(Default)]
struct DropBinding {
    registry: Option<Registry>,
    counters: HashMap<Arc<str>, Counter>,
}

/// Cheap-to-clone tracer handle.
#[derive(Clone)]
pub struct Tracer {
    buffer: Arc<Mutex<Buffer>>,
    clock: Clock,
    capacity: usize,
    enabled: bool,
    sample_rate: f64,
    /// Site this handle attributes its spans to ([`LOCAL_SITE`] unless
    /// re-scoped via [`Tracer::for_site`]).
    site: Arc<str>,
    next_trace: Arc<AtomicU64>,
    drop_binding: Arc<Mutex<DropBinding>>,
}

impl fmt::Debug for Tracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.enabled)
            .field("capacity", &self.capacity)
            .field("sample_rate", &self.sample_rate)
            .finish()
    }
}

/// splitmix64 finalizer — deterministic per-trace sampling decision.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl Tracer {
    /// Tracer retaining up to `capacity` spans (ring semantics).
    pub fn new(clock: Clock, capacity: usize, enabled: bool) -> Self {
        Tracer {
            buffer: Arc::new(Mutex::new(Buffer::default())),
            clock,
            capacity,
            enabled,
            sample_rate: 1.0,
            site: Arc::from(LOCAL_SITE),
            next_trace: Arc::new(AtomicU64::new(1)),
            drop_binding: Arc::new(Mutex::new(DropBinding::default())),
        }
    }

    /// Facade attributing this handle's spans to `site`. The buffer,
    /// sampling state and registry binding stay SHARED with the parent
    /// (one trace id still joins spans across sites); only the drop
    /// accounting label changes.
    pub fn for_site(&self, site: &str) -> Tracer {
        let mut t = self.clone();
        t.site = Arc::from(site);
        t
    }

    /// Disabled tracer (all ops are no-ops).
    pub fn disabled() -> Self {
        Tracer::new(Clock::real(), 0, false)
    }

    /// Set the head-sampling rate (fraction of traces recorded, [0, 1]).
    pub fn with_sample_rate(mut self, rate: f64) -> Self {
        self.sample_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Configured head-sampling rate.
    pub fn sample_rate(&self) -> f64 {
        self.sample_rate
    }

    /// Allocate a fresh trace id.
    pub fn new_trace(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Head-based sampling decision for a trace id: deterministic, so
    /// every hop of a request agrees without coordination.
    pub fn sample(&self, trace_id: u64) -> bool {
        if !self.enabled || trace_id == 0 || self.sample_rate <= 0.0 {
            return false;
        }
        if self.sample_rate >= 1.0 {
            return true;
        }
        let unit = (mix64(trace_id) >> 11) as f64 / (1u64 << 53) as f64;
        unit < self.sample_rate
    }

    /// Allocate a trace id together with its head-sampling decision —
    /// what a client stamps into the wire header.
    pub fn start_trace(&self) -> (u64, bool) {
        let id = self.new_trace();
        (id, self.sample(id))
    }

    /// Mirror span drops into per-site registry counters
    /// ([`SPANS_DROPPED_COUNTER`]). Binds retroactively: drops that
    /// happened before the call are added to this handle's site counter
    /// (their origin sites were not tracked yet).
    pub fn bind_registry(&self, registry: &Registry) {
        let c = registry.counter(SPANS_DROPPED_COUNTER, &labels(&[("site", &self.site)]));
        let backlog = self.buffer.lock().unwrap().dropped;
        if backlog > c.get() {
            c.add(backlog - c.get());
        }
        let mut b = self.drop_binding.lock().unwrap();
        b.counters.insert(Arc::clone(&self.site), c);
        b.registry = Some(registry.clone());
    }

    /// Spans evicted from the buffer since construction.
    pub fn dropped(&self) -> u64 {
        self.buffer.lock().unwrap().dropped
    }

    /// Start a span; it records itself when the guard drops.
    pub fn span(&self, trace_id: u64, name: &str) -> Option<SpanGuard> {
        if !self.enabled || trace_id == 0 {
            return None;
        }
        Some(SpanGuard {
            tracer: self.clone(),
            trace_id,
            name: name.to_string(),
            start: self.clock.now_secs(),
        })
    }

    /// Record a pre-built span (for spans whose timing came from
    /// elsewhere, e.g. server-reported queue/compute micros).
    pub fn record(&self, span: Span) {
        if !self.enabled || span.trace_id == 0 {
            return;
        }
        let mut buf = self.buffer.lock().unwrap();
        buf.traces.entry(span.trace_id).or_default().push(span.clone());
        buf.ring.push_back((span.trace_id, Arc::clone(&self.site)));
        while buf.ring.len() > self.capacity {
            let (victim, site) = buf.ring.pop_front().expect("ring non-empty");
            if let Some(spans) = buf.traces.get_mut(&victim) {
                if !spans.is_empty() {
                    spans.remove(0);
                }
                if spans.is_empty() {
                    buf.traces.remove(&victim);
                }
            }
            buf.dropped += 1;
            if buf.dropped_traces.len() >= DROPPED_TRACES_CAP {
                buf.dropped_traces.clear();
                buf.dropped_overflow = true;
            }
            if !buf.dropped_overflow {
                buf.dropped_traces.insert(victim);
            }
            let mut b = self.drop_binding.lock().unwrap();
            if let Some(reg) = b.registry.clone() {
                b.counters
                    .entry(Arc::clone(&site))
                    .or_insert_with(|| {
                        reg.counter(SPANS_DROPPED_COUNTER, &labels(&[("site", &site)]))
                    })
                    .inc();
            }
        }
    }

    /// All spans of one trace, ordered by start time. The view is marked
    /// partial when the buffer evicted spans belonging to this trace
    /// (or overflowed its evicted-trace bookkeeping), so readers never
    /// mistake a truncated breakdown for a complete one.
    pub fn trace(&self, trace_id: u64) -> TraceView {
        let buf = self.buffer.lock().unwrap();
        let mut spans: Vec<Span> = buf.traces.get(&trace_id).cloned().unwrap_or_default();
        spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        let partial = buf.dropped_overflow || buf.dropped_traces.contains(&trace_id);
        TraceView { spans, partial }
    }

    /// Total spans currently retained.
    pub fn len(&self) -> usize {
        self.buffer.lock().unwrap().ring.len()
    }

    /// True if no spans retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate mean duration per span name across all retained spans —
    /// the "latency by source" table.
    pub fn breakdown(&self) -> Vec<(String, f64, usize)> {
        let buf = self.buffer.lock().unwrap();
        let mut agg: HashMap<String, (f64, usize)> = HashMap::new();
        for s in buf.traces.values().flatten() {
            let e = agg.entry(s.name.clone()).or_insert((0.0, 0));
            e.0 += s.duration();
            e.1 += 1;
        }
        let mut rows: Vec<(String, f64, usize)> = agg
            .into_iter()
            .map(|(name, (sum, n))| (name, sum / n as f64, n))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

/// The spans of one trace.
pub struct TraceView {
    pub spans: Vec<Span>,
    /// True when the trace buffer evicted spans of this trace: the view
    /// is a lower bound, not the full request.
    pub partial: bool,
}

impl TraceView {
    /// Whether spans of this trace were evicted before being read.
    pub fn is_partial(&self) -> bool {
        self.partial
    }

    /// Sum of span durations by name.
    pub fn duration_of(&self, name: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.duration())
            .sum()
    }

    /// End-to-end duration (first start to last end).
    pub fn total(&self) -> f64 {
        let start = self.spans.iter().map(|s| s.start).fold(f64::INFINITY, f64::min);
        let end = self.spans.iter().map(|s| s.end).fold(f64::NEG_INFINITY, f64::max);
        if self.spans.is_empty() {
            0.0
        } else {
            end - start
        }
    }

    /// Duration of the root ([`ROOT_SPAN`]) span, if present.
    pub fn root_duration(&self) -> Option<f64> {
        self.spans
            .iter()
            .filter(|s| s.name == ROOT_SPAN)
            .map(|s| s.duration())
            .fold(None, |acc, d| Some(acc.map_or(d, |a: f64| a.max(d))))
    }

    /// Critical-path analysis: per-stage durations in [`STAGES`] order,
    /// with `other` set to the residual of the root span not covered by
    /// any named stage. Returns `None` when the trace has no root span
    /// or is partial (a truncated breakdown would be misleading).
    pub fn stage_breakdown(&self) -> Option<Vec<(&'static str, f64)>> {
        if self.partial {
            return None;
        }
        let root = self.root_duration()?;
        let mut rows: Vec<(&'static str, f64)> = Vec::with_capacity(STAGES.len());
        let mut covered = 0.0;
        for &stage in STAGES {
            if stage == "other" {
                continue;
            }
            let d = self.duration_of(stage);
            covered += d;
            rows.push((stage, d));
        }
        rows.push(("other", (root - covered).max(0.0)));
        Some(rows)
    }

    /// Render a flame-ish text view.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.spans.is_empty() {
            return "(no spans)\n".into();
        }
        let t0 = self.spans[0].start;
        for s in &self.spans {
            out.push_str(&format!(
                "{:>9.3}ms +{:>9.3}ms  {}\n",
                (s.start - t0) * 1e3,
                s.duration() * 1e3,
                s.name
            ));
        }
        if self.partial {
            out.push_str("(partial: spans were evicted from the buffer)\n");
        }
        out
    }
}

/// Folds finished traces into `request_stage_seconds{stage=...}`
/// histograms plus a `request_total_seconds` histogram of root-span
/// durations — the per-source latency breakdown of §2.3 as scrapeable
/// series rather than a per-trace table.
#[derive(Clone)]
pub struct StageRecorder {
    registry: Registry,
    stages: Vec<(&'static str, HistogramHandle)>,
    total: HistogramHandle,
    /// Per-site partial counters and per-site `wan` stage histograms,
    /// created lazily as serving sites appear.
    by_site: Arc<Mutex<SiteSeries>>,
}

#[derive(Default)]
struct SiteSeries {
    partial: HashMap<String, Counter>,
    wan: HashMap<String, HistogramHandle>,
}

impl StageRecorder {
    /// Register the stage histograms (one per [`STAGES`] label). The
    /// `wan` stage is excluded here: it is only observed site-labeled,
    /// so its series appear per serving site on first cross-site hop.
    pub fn new(registry: &Registry) -> Self {
        let stages = STAGES
            .iter()
            .filter(|&&s| s != "wan")
            .map(|&s| (s, registry.histogram(STAGE_HISTOGRAM, &labels(&[("stage", s)]))))
            .collect();
        let rec = StageRecorder {
            registry: registry.clone(),
            stages,
            total: registry.histogram("request_total_seconds", &labels(&[])),
            by_site: Arc::new(Mutex::new(SiteSeries::default())),
        };
        // Pre-create the local partial counter so the family is present
        // (at 0) in every exposition, like the other trace series.
        rec.partial_counter(LOCAL_SITE);
        rec
    }

    fn partial_counter(&self, site: &str) -> Counter {
        let mut s = self.by_site.lock().unwrap();
        s.partial
            .entry(site.to_string())
            .or_insert_with(|| {
                self.registry.counter(PARTIAL_TRACES_COUNTER, &labels(&[("site", site)]))
            })
            .clone()
    }

    fn wan_histogram(&self, site: &str) -> HistogramHandle {
        let mut s = self.by_site.lock().unwrap();
        s.wan
            .entry(site.to_string())
            .or_insert_with(|| {
                self.registry
                    .histogram(STAGE_HISTOGRAM, &labels(&[("stage", "wan"), ("site", site)]))
            })
            .clone()
    }

    /// Observe one finished trace served locally.
    pub fn observe(&self, view: &TraceView) {
        self.observe_from(view, LOCAL_SITE);
    }

    /// Observe one finished trace served by `site` (the federated
    /// gateway's final pick). Partial traces are counted per site (see
    /// [`PARTIAL_TRACES_COUNTER`]) but not folded into the breakdown; a
    /// non-zero `wan` stage folds into a site-labeled histogram so one
    /// site's WAN tax is visible on its own.
    pub fn observe_from(&self, view: &TraceView, site: &str) {
        if view.partial {
            self.partial_counter(site).inc();
            return;
        }
        let Some(rows) = view.stage_breakdown() else {
            return;
        };
        for (stage, d) in rows {
            if stage == "wan" {
                if d > 0.0 {
                    self.wan_histogram(site).observe(d);
                }
            } else if let Some((_, h)) = self.stages.iter().find(|(s, _)| *s == stage) {
                h.observe(d);
            }
        }
        if let Some(root) = view.root_duration() {
            self.total.observe(root);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn span_guard_records() {
        let clock = Clock::simulated();
        let tracer = Tracer::new(clock.clone(), 100, true);
        let tid = tracer.new_trace();
        {
            let _g = tracer.span(tid, "work");
            clock.advance(Duration::from_millis(50));
        }
        let view = tracer.trace(tid);
        assert_eq!(view.spans.len(), 1);
        assert!((view.duration_of("work") - 0.05).abs() < 1e-6);
    }

    #[test]
    fn disabled_tracer_is_noop() {
        let tracer = Tracer::disabled();
        let tid = tracer.new_trace();
        assert!(tracer.span(tid, "x").is_none());
        tracer.record(Span { trace_id: tid, name: "y".into(), start: 0.0, end: 1.0 });
        assert!(tracer.is_empty());
        assert!(!tracer.sample(tid));
    }

    #[test]
    fn capacity_bounded() {
        let tracer = Tracer::new(Clock::simulated(), 5, true);
        for i in 0..20 {
            tracer.record(Span { trace_id: 1, name: format!("s{i}"), start: 0.0, end: 1.0 });
        }
        assert_eq!(tracer.len(), 5);
        assert_eq!(tracer.dropped(), 15);
    }

    #[test]
    fn trace_view_ordering_and_total() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        tracer.record(Span { trace_id: 1, name: "compute".into(), start: 2.0, end: 5.0 });
        tracer.record(Span { trace_id: 1, name: "queue".into(), start: 0.0, end: 2.0 });
        tracer.record(Span { trace_id: 2, name: "other".into(), start: 0.0, end: 9.0 });
        let v = tracer.trace(1);
        assert_eq!(v.spans[0].name, "queue");
        assert_eq!(v.total(), 5.0);
        assert!(v.render().contains("compute"));
    }

    #[test]
    fn breakdown_aggregates_by_name() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        for i in 0..4 {
            tracer.record(Span { trace_id: i + 1, name: "queue".into(), start: 0.0, end: 1.0 });
            tracer.record(Span { trace_id: i + 1, name: "compute".into(), start: 1.0, end: 4.0 });
        }
        let rows = tracer.breakdown();
        assert_eq!(rows[0].0, "compute");
        assert_eq!(rows[0].2, 4);
        assert!((rows[0].1 - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_trace_id_not_recorded() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        assert!(tracer.span(0, "x").is_none());
        tracer.record(Span { trace_id: 0, name: "x".into(), start: 0.0, end: 1.0 });
        assert!(tracer.is_empty());
    }

    #[test]
    fn dropped_spans_counted_and_exported() {
        let registry = Registry::new();
        let tracer = Tracer::new(Clock::simulated(), 2, true);
        tracer.record(Span { trace_id: 1, name: "a".into(), start: 0.0, end: 1.0 });
        tracer.record(Span { trace_id: 2, name: "b".into(), start: 0.0, end: 1.0 });
        tracer.bind_registry(&registry);
        tracer.record(Span { trace_id: 3, name: "c".into(), start: 0.0, end: 1.0 });
        assert_eq!(tracer.dropped(), 1);
        let c = registry.counter(SPANS_DROPPED_COUNTER, &labels(&[("site", LOCAL_SITE)]));
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn dropped_spans_attributed_to_recording_site() {
        let registry = Registry::new();
        let tracer = Tracer::new(Clock::simulated(), 2, true);
        tracer.bind_registry(&registry);
        let remote = tracer.for_site("nrp");
        remote.record(Span { trace_id: 1, name: "a".into(), start: 0.0, end: 1.0 });
        remote.record(Span { trace_id: 2, name: "b".into(), start: 0.0, end: 1.0 });
        // Overflow evicts remote-recorded spans: the drop lands on nrp's
        // counter, not on local's — and the shared buffer still joins.
        tracer.record(Span { trace_id: 3, name: "c".into(), start: 0.0, end: 1.0 });
        tracer.record(Span { trace_id: 4, name: "d".into(), start: 0.0, end: 1.0 });
        let local = registry.counter(SPANS_DROPPED_COUNTER, &labels(&[("site", LOCAL_SITE)]));
        let nrp = registry.counter(SPANS_DROPPED_COUNTER, &labels(&[("site", "nrp")]));
        assert_eq!(nrp.get(), 2, "both evictions were nrp-recorded spans");
        assert_eq!(local.get(), 0);
        assert_eq!(tracer.len(), 2);
    }

    #[test]
    fn evicted_trace_flagged_partial() {
        let tracer = Tracer::new(Clock::simulated(), 2, true);
        tracer.record(Span { trace_id: 7, name: "a".into(), start: 0.0, end: 1.0 });
        tracer.record(Span { trace_id: 7, name: "b".into(), start: 1.0, end: 2.0 });
        tracer.record(Span { trace_id: 7, name: "c".into(), start: 2.0, end: 3.0 });
        let v = tracer.trace(7);
        assert!(v.is_partial());
        assert_eq!(v.spans.len(), 2);
        // An untouched trace stays complete.
        tracer.record(Span { trace_id: 8, name: "d".into(), start: 0.0, end: 1.0 });
        // 8's record evicted another span of 7, not of 8.
        assert!(!tracer.trace(8).is_partial());
    }

    #[test]
    fn sampling_deterministic_and_bounded() {
        let tracer = Tracer::new(Clock::simulated(), 10, true).with_sample_rate(0.5);
        let hits: Vec<bool> = (1..=1000u64).map(|id| tracer.sample(id)).collect();
        let again: Vec<bool> = (1..=1000u64).map(|id| tracer.sample(id)).collect();
        assert_eq!(hits, again, "sampling must be deterministic per id");
        let n = hits.iter().filter(|&&b| b).count();
        assert!(n > 350 && n < 650, "rate 0.5 sampled {n}/1000");
        let all = Tracer::new(Clock::simulated(), 10, true).with_sample_rate(1.0);
        assert!((1..=100u64).all(|id| all.sample(id)));
        let none = Tracer::new(Clock::simulated(), 10, true).with_sample_rate(0.0);
        assert!((1..=100u64).all(|id| !none.sample(id)));
    }

    #[test]
    fn stage_breakdown_covers_root() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        tracer.record(Span { trace_id: 1, name: ROOT_SPAN.into(), start: 0.0, end: 10.0 });
        tracer.record(Span { trace_id: 1, name: "admit".into(), start: 0.0, end: 1.0 });
        tracer.record(Span { trace_id: 1, name: "queue".into(), start: 1.0, end: 5.0 });
        tracer.record(Span { trace_id: 1, name: "compute".into(), start: 5.0, end: 9.0 });
        let rows = tracer.trace(1).stage_breakdown().expect("complete trace");
        let get = |n: &str| rows.iter().find(|(s, _)| *s == n).unwrap().1;
        assert!((get("queue") - 4.0).abs() < 1e-9);
        assert!((get("other") - 1.0).abs() < 1e-9);
        let sum: f64 = rows.iter().map(|(_, d)| d).sum();
        assert!((sum - 10.0).abs() < 1e-9, "stages must reconstruct the root");
    }

    #[test]
    fn stage_recorder_observes_histograms() {
        let registry = Registry::new();
        let rec = StageRecorder::new(&registry);
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        tracer.record(Span { trace_id: 1, name: ROOT_SPAN.into(), start: 0.0, end: 4.0 });
        tracer.record(Span { trace_id: 1, name: "compute".into(), start: 1.0, end: 4.0 });
        rec.observe(&tracer.trace(1));
        let h = registry.histogram(STAGE_HISTOGRAM, &labels(&[("stage", "compute")]));
        assert_eq!(h.snapshot().count(), 1);
        assert!((h.snapshot().sum() - 3.0).abs() < 1e-9);
        // A partial trace is counted, not observed.
        let small = Tracer::new(Clock::simulated(), 1, true);
        small.record(Span { trace_id: 2, name: ROOT_SPAN.into(), start: 0.0, end: 1.0 });
        small.record(Span { trace_id: 2, name: "compute".into(), start: 0.0, end: 1.0 });
        rec.observe(&small.trace(2));
        let partial = registry.counter(PARTIAL_TRACES_COUNTER, &labels(&[("site", LOCAL_SITE)]));
        assert_eq!(partial.get(), 1);
        assert_eq!(h.snapshot().count(), 1);
    }

    #[test]
    fn wan_stage_folds_site_labeled() {
        let registry = Registry::new();
        let rec = StageRecorder::new(&registry);
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        tracer.record(Span { trace_id: 1, name: ROOT_SPAN.into(), start: 0.0, end: 5.0 });
        tracer.record(Span { trace_id: 1, name: "wan".into(), start: 0.0, end: 2.0 });
        tracer.record(Span { trace_id: 1, name: "compute".into(), start: 2.0, end: 5.0 });
        let view = tracer.trace(1);
        let rows = view.stage_breakdown().expect("complete trace");
        let get = |n: &str| rows.iter().find(|(s, _)| *s == n).unwrap().1;
        assert!((get("wan") - 2.0).abs() < 1e-9);
        let sum: f64 = rows.iter().map(|(_, d)| d).sum();
        assert!((sum - 5.0).abs() < 1e-9, "wan must stay inside the reconstruction");
        rec.observe_from(&view, "uchicago");
        let h = registry
            .histogram(STAGE_HISTOGRAM, &labels(&[("stage", "wan"), ("site", "uchicago")]));
        assert_eq!(h.snapshot().count(), 1);
        assert!((h.snapshot().sum() - 2.0).abs() < 1e-9);
        // The same trace folded without a site attributes its wan time
        // to the local label — wan series only exist where observed.
        rec.observe(&tracer.trace(1));
        let local_wan = registry
            .histogram(STAGE_HISTOGRAM, &labels(&[("stage", "wan"), ("site", LOCAL_SITE)]));
        assert_eq!(local_wan.snapshot().count(), 1);
    }
}
