//! Span tracing — the OpenTelemetry/Tempo analogue (§2.3).
//!
//! The paper uses tracing for "a more detailed analysis of inference
//! request flows and performance bottlenecks". Here a [`Tracer`] collects
//! [`Span`]s (named, timed segments tied to a trace id) into a bounded
//! in-memory buffer; [`TraceView`] reassembles a request's spans into the
//! per-source latency breakdown (client -> gateway -> queue -> compute)
//! that the §2.3 "breakdown of total request latency by source" metric
//! reports.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::collections::VecDeque;

use crate::util::clock::Clock;

/// One finished span.
#[derive(Clone, Debug)]
pub struct Span {
    pub trace_id: u64,
    pub name: String,
    /// Clock-seconds start/end.
    pub start: f64,
    pub end: f64,
}

impl Span {
    /// Span duration in seconds.
    pub fn duration(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }
}

/// In-flight span guard: records the span on drop (RAII).
pub struct SpanGuard {
    tracer: Tracer,
    trace_id: u64,
    name: String,
    start: f64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = self.tracer.clock.now_secs();
        self.tracer.record(Span {
            trace_id: self.trace_id,
            name: std::mem::take(&mut self.name),
            start: self.start,
            end,
        });
    }
}

#[derive(Default)]
struct Buffer {
    spans: VecDeque<Span>,
}

/// Cheap-to-clone tracer handle.
#[derive(Clone)]
pub struct Tracer {
    buffer: Arc<Mutex<Buffer>>,
    clock: Clock,
    capacity: usize,
    enabled: bool,
    next_trace: Arc<AtomicU64>,
}

impl Tracer {
    /// Tracer retaining up to `capacity` spans (ring semantics).
    pub fn new(clock: Clock, capacity: usize, enabled: bool) -> Self {
        Tracer {
            buffer: Arc::new(Mutex::new(Buffer::default())),
            clock,
            capacity,
            enabled,
            next_trace: Arc::new(AtomicU64::new(1)),
        }
    }

    /// Disabled tracer (all ops are no-ops).
    pub fn disabled() -> Self {
        Tracer::new(Clock::real(), 0, false)
    }

    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Allocate a fresh trace id.
    pub fn new_trace(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Start a span; it records itself when the guard drops.
    pub fn span(&self, trace_id: u64, name: &str) -> Option<SpanGuard> {
        if !self.enabled || trace_id == 0 {
            return None;
        }
        Some(SpanGuard {
            tracer: self.clone(),
            trace_id,
            name: name.to_string(),
            start: self.clock.now_secs(),
        })
    }

    /// Record a pre-built span (for spans whose timing came from
    /// elsewhere, e.g. server-reported queue/compute micros).
    pub fn record(&self, span: Span) {
        if !self.enabled {
            return;
        }
        let mut buf = self.buffer.lock().unwrap();
        buf.spans.push_back(span);
        while buf.spans.len() > self.capacity {
            buf.spans.pop_front();
        }
    }

    /// All spans of one trace, ordered by start time.
    pub fn trace(&self, trace_id: u64) -> TraceView {
        let buf = self.buffer.lock().unwrap();
        let mut spans: Vec<Span> = buf
            .spans
            .iter()
            .filter(|s| s.trace_id == trace_id)
            .cloned()
            .collect();
        spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        TraceView { spans }
    }

    /// Total spans currently retained.
    pub fn len(&self) -> usize {
        self.buffer.lock().unwrap().spans.len()
    }

    /// True if no spans retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate mean duration per span name across all retained spans —
    /// the "latency by source" table.
    pub fn breakdown(&self) -> Vec<(String, f64, usize)> {
        let buf = self.buffer.lock().unwrap();
        let mut agg: HashMap<String, (f64, usize)> = HashMap::new();
        for s in &buf.spans {
            let e = agg.entry(s.name.clone()).or_insert((0.0, 0));
            e.0 += s.duration();
            e.1 += 1;
        }
        let mut rows: Vec<(String, f64, usize)> = agg
            .into_iter()
            .map(|(name, (sum, n))| (name, sum / n as f64, n))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

/// The spans of one trace.
pub struct TraceView {
    pub spans: Vec<Span>,
}

impl TraceView {
    /// Sum of span durations by name.
    pub fn duration_of(&self, name: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.duration())
            .sum()
    }

    /// End-to-end duration (first start to last end).
    pub fn total(&self) -> f64 {
        let start = self.spans.iter().map(|s| s.start).fold(f64::INFINITY, f64::min);
        let end = self.spans.iter().map(|s| s.end).fold(f64::NEG_INFINITY, f64::max);
        if self.spans.is_empty() {
            0.0
        } else {
            end - start
        }
    }

    /// Render a flame-ish text view.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.spans.is_empty() {
            return "(no spans)\n".into();
        }
        let t0 = self.spans[0].start;
        for s in &self.spans {
            out.push_str(&format!(
                "{:>9.3}ms +{:>9.3}ms  {}\n",
                (s.start - t0) * 1e3,
                s.duration() * 1e3,
                s.name
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn span_guard_records() {
        let clock = Clock::simulated();
        let tracer = Tracer::new(clock.clone(), 100, true);
        let tid = tracer.new_trace();
        {
            let _g = tracer.span(tid, "work");
            clock.advance(Duration::from_millis(50));
        }
        let view = tracer.trace(tid);
        assert_eq!(view.spans.len(), 1);
        assert!((view.duration_of("work") - 0.05).abs() < 1e-6);
    }

    #[test]
    fn disabled_tracer_is_noop() {
        let tracer = Tracer::disabled();
        let tid = tracer.new_trace();
        assert!(tracer.span(tid, "x").is_none());
        tracer.record(Span { trace_id: tid, name: "y".into(), start: 0.0, end: 1.0 });
        assert!(tracer.is_empty());
    }

    #[test]
    fn capacity_bounded() {
        let tracer = Tracer::new(Clock::simulated(), 5, true);
        for i in 0..20 {
            tracer.record(Span { trace_id: 1, name: format!("s{i}"), start: 0.0, end: 1.0 });
        }
        assert_eq!(tracer.len(), 5);
    }

    #[test]
    fn trace_view_ordering_and_total() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        tracer.record(Span { trace_id: 1, name: "compute".into(), start: 2.0, end: 5.0 });
        tracer.record(Span { trace_id: 1, name: "queue".into(), start: 0.0, end: 2.0 });
        tracer.record(Span { trace_id: 2, name: "other".into(), start: 0.0, end: 9.0 });
        let v = tracer.trace(1);
        assert_eq!(v.spans[0].name, "queue");
        assert_eq!(v.total(), 5.0);
        assert!(v.render().contains("compute"));
    }

    #[test]
    fn breakdown_aggregates_by_name() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        for i in 0..4 {
            tracer.record(Span { trace_id: i, name: "queue".into(), start: 0.0, end: 1.0 });
            tracer.record(Span { trace_id: i, name: "compute".into(), start: 1.0, end: 4.0 });
        }
        let rows = tracer.breakdown();
        assert_eq!(rows[0].0, "compute");
        assert_eq!(rows[0].2, 4);
        assert!((rows[0].1 - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_trace_id_not_recorded() {
        let tracer = Tracer::new(Clock::simulated(), 100, true);
        assert!(tracer.span(0, "x").is_none());
    }
}
