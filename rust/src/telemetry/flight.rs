//! Control-plane flight recorder — the decision ledger.
//!
//! Eight control loops (placement, per-model + CPU scalers, the inert
//! global autoscaler, rebalancer, federation router, rollback, ramp)
//! mutate the fleet; before this module their decisions were observable
//! only through side effects. A [`FlightRecorder`] keeps a bounded,
//! clock-stamped ring of structured [`DecisionEvent`]s — who decided
//! what, from which inputs, over which rejected alternatives — and
//! [`FlightRecorder::explain`] joins them into the causal chains an
//! operator reads during an incident (site kill → `site_outage` latch →
//! budget shift → spillover → repatriation).
//!
//! Loop health rides alongside: [`LoopTicker`] wraps each loop body in a
//! `control_loop_tick_seconds{loop=...}` histogram and a
//! `control_loop_last_run_seconds{loop=...}` staleness gauge, and every
//! recorded event bumps `control_decisions_total{loop=...,kind=...}` —
//! a wedged loop is an alertable signal instead of silent drift.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use crate::metrics::registry::{labels, Gauge, HistogramHandle, Registry};
use crate::util::clock::Clock;

/// Counter of recorded decisions, labeled `{loop=...,kind=...}`.
pub const DECISIONS_COUNTER: &str = "control_decisions_total";

/// Histogram of loop-body durations (clock seconds), labeled `{loop=...}`.
pub const LOOP_TICK_HISTOGRAM: &str = "control_loop_tick_seconds";

/// Gauge of each loop's last completed tick (clock seconds), labeled
/// `{loop=...}` — `now - gauge` is the loop's staleness.
pub const LOOP_LAST_RUN_GAUGE: &str = "control_loop_last_run_seconds";

/// Every actor-loop label emitted on decision events and loop-health
/// series. Documented in OPERATIONS.md (test-enforced).
pub const LOOP_LABELS: &[&str] = &[
    "placement",
    "per_model_scaler",
    "cpu_scaler",
    "autoscaler",
    "rebalancer",
    "federation_router",
    "rollback",
    "ramp",
];

/// Every decision kind a control loop can record. Documented in
/// OPERATIONS.md (test-enforced).
pub const DECISION_KINDS: &[&str] = &[
    "grow",
    "shrink",
    "repair",
    "swap",
    "scale_target",
    "cpu_target",
    "budget_shift",
    "site_outage",
    "site_recovered",
    "spillover",
    "failover",
    "repatriation",
    "rollback",
    "ramp_advance",
];

/// One control-plane decision: who decided what, from which inputs.
#[derive(Clone, Debug)]
pub struct DecisionEvent {
    /// Clock seconds at record time (stamped by the recorder).
    pub at: f64,
    /// Actor loop (one of [`LOOP_LABELS`]).
    pub loop_name: &'static str,
    /// Decision kind (one of [`DECISION_KINDS`]).
    pub kind: &'static str,
    /// Model the decision concerns, when model-scoped.
    pub model: Option<String>,
    /// Site the decision concerns, when site-scoped.
    pub site: Option<String>,
    /// Model version, when version-scoped (canary/rollback/ramp).
    pub version: Option<String>,
    /// Compact numeric snapshot of the inputs the loop decided from
    /// (demand, budgets, thresholds, derived knees).
    pub inputs: Vec<(&'static str, f64)>,
    /// The action taken, rendered for humans.
    pub action: String,
    /// Rejected alternatives and their scores, where cheap to capture.
    pub alternatives: Vec<(String, f64)>,
}

impl DecisionEvent {
    /// Event skeleton; the recorder stamps `at` when it is recorded.
    pub fn new(loop_name: &'static str, kind: &'static str) -> Self {
        debug_assert!(LOOP_LABELS.contains(&loop_name), "undeclared loop '{loop_name}'");
        debug_assert!(DECISION_KINDS.contains(&kind), "undeclared kind '{kind}'");
        DecisionEvent {
            at: 0.0,
            loop_name,
            kind,
            model: None,
            site: None,
            version: None,
            inputs: Vec::new(),
            action: String::new(),
            alternatives: Vec::new(),
        }
    }

    /// Scope to a model.
    pub fn model(mut self, model: &str) -> Self {
        self.model = Some(model.to_string());
        self
    }

    /// Scope to a site.
    pub fn site(mut self, site: &str) -> Self {
        self.site = Some(site.to_string());
        self
    }

    /// Scope to a model version.
    pub fn version(mut self, version: &str) -> Self {
        self.version = Some(version.to_string());
        self
    }

    /// Attach one numeric input.
    pub fn input(mut self, key: &'static str, value: f64) -> Self {
        self.inputs.push((key, value));
        self
    }

    /// Set the human-rendered action.
    pub fn action(mut self, action: impl Into<String>) -> Self {
        self.action = action.into();
        self
    }

    /// Attach one rejected alternative and its score.
    pub fn alternative(mut self, name: impl Into<String>, score: f64) -> Self {
        self.alternatives.push((name.into(), score));
        self
    }

    /// One explain line: `t=12.3s [rebalancer] budget_shift site=nrp ...`.
    pub fn render(&self) -> String {
        let mut out = format!("t={:.1}s [{}] {}", self.at, self.loop_name, self.kind);
        if let Some(m) = &self.model {
            let _ = write!(out, " model={m}");
        }
        if let Some(s) = &self.site {
            let _ = write!(out, " site={s}");
        }
        if let Some(v) = &self.version {
            let _ = write!(out, " version={v}");
        }
        if !self.inputs.is_empty() {
            out.push_str(" inputs:");
            for (k, v) in &self.inputs {
                let _ = write!(out, " {k}={v:.3}");
            }
        }
        if !self.action.is_empty() {
            let _ = write!(out, " -> {}", self.action);
        }
        if !self.alternatives.is_empty() {
            out.push_str(" (rejected:");
            for (name, score) in &self.alternatives {
                let _ = write!(out, " {name}={score:.3}");
            }
            out.push(')');
        }
        out
    }
}

/// Filter for [`FlightRecorder::explain`] / [`FlightRecorder::events`].
/// Label filters keep matching events plus unscoped ones (a fleet-wide
/// budget shift is part of any model's story); `since` bounds the window.
#[derive(Clone, Debug, Default)]
pub struct ExplainFilter {
    pub model: Option<String>,
    pub site: Option<String>,
    /// Only events at or after this clock time; `None` falls back to the
    /// configured explain horizon before now.
    pub since: Option<f64>,
}

impl ExplainFilter {
    fn matches(&self, ev: &DecisionEvent) -> bool {
        if let Some(m) = &self.model {
            if ev.model.as_deref().is_some_and(|em| em != m && !em.starts_with(&format!("{m}@"))) {
                return false;
            }
        }
        if let Some(s) = &self.site {
            if ev.site.as_deref().is_some_and(|es| es != s) {
                return false;
            }
        }
        true
    }
}

/// One joined outage incident: the causal chain `explain` renders and
/// the observability bench asserts link by link.
#[derive(Clone, Debug)]
pub struct OutageChain {
    pub site: String,
    pub outage: DecisionEvent,
    /// First budget shift after the outage latched (the rebalancer
    /// moving pods off the dead site).
    pub budget_shift: Option<DecisionEvent>,
    /// First router spillover/failover after the outage.
    pub spillover: Option<DecisionEvent>,
    /// The site's recovery, when it happened inside the window.
    pub recovered: Option<DecisionEvent>,
    /// First post-recovery pick of the site (traffic coming home).
    pub repatriation: Option<DecisionEvent>,
}

impl OutageChain {
    /// All five links present.
    pub fn complete(&self) -> bool {
        self.budget_shift.is_some()
            && self.spillover.is_some()
            && self.recovered.is_some()
            && self.repatriation.is_some()
    }

    /// Links are in non-decreasing timestamp order.
    pub fn in_order(&self) -> bool {
        let mut prev = self.outage.at;
        for ev in [&self.budget_shift, &self.spillover, &self.recovered, &self.repatriation]
            .into_iter()
            .flatten()
        {
            if ev.at < prev {
                return false;
            }
            prev = ev.at;
        }
        true
    }
}

/// Bounded, clock-stamped ring of [`DecisionEvent`]s shared by every
/// control loop of one deployment.
pub struct FlightRecorder {
    clock: Clock,
    capacity: usize,
    horizon: f64,
    registry: Registry,
    ring: Mutex<VecDeque<DecisionEvent>>,
}

impl FlightRecorder {
    /// Recorder retaining up to `capacity` events; `horizon` (seconds)
    /// is how far back `explain` looks when no `since` bound is given.
    pub fn new(clock: Clock, capacity: usize, horizon: f64, registry: Registry) -> Self {
        FlightRecorder {
            clock,
            capacity: capacity.max(1),
            horizon,
            registry,
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Stamp and retain one decision; bumps
    /// `control_decisions_total{loop=...,kind=...}`.
    pub fn record(&self, mut ev: DecisionEvent) {
        ev.at = self.clock.now_secs();
        self.registry
            .counter(DECISIONS_COUNTER, &labels(&[("loop", ev.loop_name), ("kind", ev.kind)]))
            .inc();
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(ev);
        while ring.len() > self.capacity {
            ring.pop_front();
        }
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<DecisionEvent> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Retained events matching `filter`, oldest first.
    pub fn events_matching(&self, filter: &ExplainFilter) -> Vec<DecisionEvent> {
        let since = filter.since.unwrap_or_else(|| self.clock.now_secs() - self.horizon);
        self.ring
            .lock()
            .unwrap()
            .iter()
            .filter(|ev| ev.at >= since && filter.matches(ev))
            .cloned()
            .collect()
    }

    /// Number of retained events.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// True when nothing has been recorded (or everything was evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Join retained events into per-site outage incident chains,
    /// oldest incident first (unfiltered: incident joining needs the
    /// fleet-wide ledger, not a label slice).
    pub fn outage_chains(&self) -> Vec<OutageChain> {
        let events = self.events();
        let mut chains = Vec::new();
        for (i, ev) in events.iter().enumerate() {
            if ev.kind != "site_outage" {
                continue;
            }
            let site = ev.site.clone().unwrap_or_default();
            let after = &events[i..];
            let find = |kind: &str, same_site: bool, not_before: f64| {
                after
                    .iter()
                    .find(|e| {
                        e.kind == kind
                            && e.at >= not_before
                            && (!same_site || e.site.as_deref() == Some(site.as_str()))
                    })
                    .cloned()
            };
            let recovered = find("site_recovered", true, ev.at);
            let repatriation = recovered
                .as_ref()
                .and_then(|r| find("repatriation", true, r.at));
            chains.push(OutageChain {
                budget_shift: find("budget_shift", false, ev.at),
                spillover: find("spillover", false, ev.at).or_else(|| find("failover", false, ev.at)),
                recovered,
                repatriation,
                site,
                outage: ev.clone(),
            });
        }
        chains
    }

    /// Text rendering of the filtered ledger plus joined outage chains —
    /// the `supersonic explain` / metrics `/debug` payload.
    pub fn explain(&self, filter: &ExplainFilter) -> String {
        let events = self.events_matching(filter);
        let mut out = String::new();
        let scope = |label: &str, v: &Option<String>| match v {
            Some(v) => format!(" {label}={v}"),
            None => String::new(),
        };
        let _ = writeln!(
            out,
            "== control-plane explain{}{} ({} events, t={:.1}s) ==",
            scope("model", &filter.model),
            scope("site", &filter.site),
            events.len(),
            self.clock.now_secs(),
        );
        for ev in &events {
            let _ = writeln!(out, "{}", ev.render());
        }
        let since = filter.since.unwrap_or_else(|| self.clock.now_secs() - self.horizon);
        for chain in self.outage_chains() {
            if chain.outage.at < since {
                continue;
            }
            if let Some(s) = &filter.site {
                if &chain.site != s {
                    continue;
                }
            }
            let _ = writeln!(
                out,
                "\n-- incident: site '{}' outage at t={:.1}s --",
                chain.site, chain.outage.at
            );
            let links: [(&str, &Option<DecisionEvent>); 4] = [
                ("budget_shift", &chain.budget_shift),
                ("spillover", &chain.spillover),
                ("recovered", &chain.recovered),
                ("repatriation", &chain.repatriation),
            ];
            let _ = writeln!(out, "  1. {}", chain.outage.render());
            let mut n = 2;
            for (name, link) in links {
                match link {
                    Some(ev) => {
                        let _ = writeln!(out, "  {n}. {}", ev.render());
                        n += 1;
                    }
                    None => {
                        let _ = writeln!(out, "  -  {name}: (not yet)");
                    }
                }
            }
        }
        out
    }
}

/// Late-installable recorder slot: control loops are constructed before
/// the deployment builds the recorder, so each holds a cheap handle that
/// no-ops until [`RecorderHandle::install`] runs (mirrors the cluster's
/// `set_reconcile_hook` pattern — constructor signatures stay put).
#[derive(Clone, Default)]
pub struct RecorderHandle {
    inner: Arc<Mutex<Option<Arc<FlightRecorder>>>>,
}

impl RecorderHandle {
    /// Point this handle (and every clone of it) at a live recorder.
    pub fn install(&self, rec: Arc<FlightRecorder>) {
        *self.inner.lock().unwrap() = Some(rec);
    }

    /// True once a recorder is installed.
    pub fn is_installed(&self) -> bool {
        self.inner.lock().unwrap().is_some()
    }

    /// Record `ev` if a recorder is installed; no-op otherwise.
    pub fn record(&self, ev: DecisionEvent) {
        let rec = self.inner.lock().unwrap().clone();
        if let Some(rec) = rec {
            rec.record(ev);
        }
    }
}

/// Loop-health instrumentation: wraps each loop body in a tick-duration
/// histogram and a last-run staleness gauge (both clock time, so
/// simulated-clock tests stay deterministic).
pub struct LoopTicker {
    clock: Clock,
    hist: HistogramHandle,
    last_run: Gauge,
}

impl LoopTicker {
    /// Register this loop's health series.
    pub fn new(registry: &Registry, clock: Clock, loop_name: &str) -> Self {
        LoopTicker {
            hist: registry.histogram(LOOP_TICK_HISTOGRAM, &labels(&[("loop", loop_name)])),
            last_run: registry.gauge(LOOP_LAST_RUN_GAUGE, &labels(&[("loop", loop_name)])),
            clock,
        }
    }

    /// Run one loop body, observing its duration and stamping the
    /// last-run gauge on completion.
    pub fn tick<T>(&self, body: impl FnOnce() -> T) -> T {
        let t0 = self.clock.now_secs();
        let out = body();
        let now = self.clock.now_secs();
        self.hist.observe((now - t0).max(0.0));
        self.last_run.set(now);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn recorder(capacity: usize) -> (Clock, Arc<FlightRecorder>, Registry) {
        let clock = Clock::simulated();
        let registry = Registry::new();
        let rec = Arc::new(FlightRecorder::new(clock.clone(), capacity, 600.0, registry.clone()));
        (clock, rec, registry)
    }

    #[test]
    fn ring_bounded_and_counted() {
        let (clock, rec, registry) = recorder(3);
        for _ in 0..5 {
            clock.advance(Duration::from_secs(1));
            rec.record(DecisionEvent::new("rebalancer", "budget_shift").site("nrp"));
        }
        assert_eq!(rec.len(), 3);
        let c = registry.counter(
            DECISIONS_COUNTER,
            &labels(&[("loop", "rebalancer"), ("kind", "budget_shift")]),
        );
        assert_eq!(c.get(), 5, "evictions do not uncount decisions");
        let events = rec.events();
        assert!((events[0].at - 3.0).abs() < 1e-9, "oldest retained is the 3rd");
    }

    #[test]
    fn filter_scopes_by_label_and_time() {
        let (clock, rec, _r) = recorder(64);
        clock.advance(Duration::from_secs(1));
        rec.record(DecisionEvent::new("per_model_scaler", "scale_target").model("cnn"));
        clock.advance(Duration::from_secs(1));
        rec.record(DecisionEvent::new("per_model_scaler", "scale_target").model("gnn"));
        clock.advance(Duration::from_secs(1));
        rec.record(DecisionEvent::new("rebalancer", "budget_shift").site("nrp"));
        let f = ExplainFilter { model: Some("cnn".into()), ..Default::default() };
        let evs = rec.events_matching(&f);
        // The unscoped-by-model budget shift stays in cnn's story.
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().all(|e| e.model.as_deref() != Some("gnn")));
        let f = ExplainFilter { since: Some(2.5), ..Default::default() };
        assert_eq!(rec.events_matching(&f).len(), 1);
        // Versioned serving names match their base-model filter.
        rec.record(DecisionEvent::new("rollback", "rollback").model("cnn@v2"));
        let f = ExplainFilter { model: Some("cnn".into()), ..Default::default() };
        assert_eq!(rec.events_matching(&f).len(), 3);
    }

    #[test]
    fn outage_chain_joins_in_order() {
        let (clock, rec, _r) = recorder(64);
        let step = |c: &Clock| c.advance(Duration::from_secs(1));
        step(&clock);
        rec.record(DecisionEvent::new("federation_router", "spillover").site("nrp"));
        step(&clock);
        rec.record(DecisionEvent::new("rebalancer", "site_outage").site("purdue"));
        step(&clock);
        rec.record(DecisionEvent::new("rebalancer", "budget_shift").site("nrp"));
        step(&clock);
        rec.record(DecisionEvent::new("federation_router", "spillover").site("uchicago"));
        step(&clock);
        rec.record(DecisionEvent::new("rebalancer", "site_recovered").site("purdue"));
        step(&clock);
        rec.record(DecisionEvent::new("federation_router", "repatriation").site("purdue"));
        let chains = rec.outage_chains();
        assert_eq!(chains.len(), 1);
        let c = &chains[0];
        assert_eq!(c.site, "purdue");
        assert!(c.complete(), "all links present: {c:?}");
        assert!(c.in_order());
        // The pre-outage spillover must not be picked as the chain link.
        assert!(c.spillover.as_ref().unwrap().at > c.outage.at);
        let text = rec.explain(&ExplainFilter::default());
        assert!(text.contains("incident: site 'purdue'"));
        assert!(text.contains("site_outage"));
        assert!(text.contains("repatriation"));
    }

    #[test]
    fn handle_noops_until_installed() {
        let handle = RecorderHandle::default();
        handle.record(DecisionEvent::new("ramp", "ramp_advance").model("cnn"));
        let (_clock, rec, _r) = recorder(8);
        handle.install(Arc::clone(&rec));
        handle.record(DecisionEvent::new("ramp", "ramp_advance").model("cnn"));
        assert_eq!(rec.len(), 1, "pre-install events are dropped, post-install kept");
    }

    #[test]
    fn loop_ticker_observes_clock_time() {
        let clock = Clock::simulated();
        let registry = Registry::new();
        let t = LoopTicker::new(&registry, clock.clone(), "rebalancer");
        clock.advance(Duration::from_secs(5));
        t.tick(|| clock.advance(Duration::from_millis(250)));
        let h = registry.histogram(LOOP_TICK_HISTOGRAM, &labels(&[("loop", "rebalancer")]));
        assert_eq!(h.snapshot().count(), 1);
        assert!((h.snapshot().sum() - 0.25).abs() < 1e-9);
        let g = registry.gauge(LOOP_LAST_RUN_GAUGE, &labels(&[("loop", "rebalancer")]));
        assert!((g.get() - 5.25).abs() < 1e-5);
    }

    #[test]
    fn render_includes_inputs_and_alternatives() {
        let ev = DecisionEvent::new("placement", "grow")
            .model("cnn")
            .site("purdue")
            .input("demand", 120.0)
            .action("load cnn on pod-3")
            .alternative("pod-1", 0.4);
        let line = ev.render();
        assert!(line.contains("[placement] grow"));
        assert!(line.contains("model=cnn"));
        assert!(line.contains("demand=120.000"));
        assert!(line.contains("pod-3"));
        assert!(line.contains("pod-1=0.400"));
    }
}
