//! SLO burn-rate alerting over the metric store — the Grafana-alerting
//! analogue of §2.3, shaped after the multi-window burn-rate rules the
//! CMS-scale deployments page on (fast window catches an active incident,
//! slow window suppresses blips).
//!
//! Per-model targets come from the `observability.slos` config list. For
//! each model the engine derives two burn rates from the store:
//!
//! * **latency**: fraction of OK requests slower than the `latency_p99`
//!   target, divided by the implied 1% budget ([`LATENCY_BUDGET`]);
//! * **error rate**: fraction of non-OK responses divided by the
//!   configured `error_budget`.
//!
//! An alert fires when *both* the fast and slow window burn exceed
//! `observability.slo_burn_threshold`, and resolves when the fast window
//! drops back under it. Transitions are exported as
//! `slo_alert_active{alert=...,model=...}` gauges and appended to a
//! structured alert log ([`SloEngine::events`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::schema::ObservabilityConfig;
use crate::metrics::registry::{labels, Gauge, Registry};
use crate::metrics::store::MetricStore;
use crate::util::clock::Clock;

/// Every alert name the engine can fire (`alert=` label values).
pub const SLO_ALERTS: &[&str] = &["latency_burn_rate", "error_budget_burn_rate"];

/// Gauge series exporting alert state (1 = firing, 0 = resolved).
pub const ALERT_GAUGE: &str = "slo_alert_active";

/// Error budget implied by a p99 latency objective: 1% of requests may
/// exceed the target.
pub const LATENCY_BUDGET: f64 = 0.01;

/// Per-model histogram of OK request latency, observed by the gateway
/// and read back by the engine to count target breaches.
pub const MODEL_LATENCY_HIST: &str = "gateway_model_latency_seconds";

/// Per-model counter of all responses, observed by the gateway.
pub const MODEL_REQUESTS_COUNTER: &str = "gateway_model_requests_total";

/// Per-model counter of non-OK responses, observed by the gateway.
pub const MODEL_ERRORS_COUNTER: &str = "gateway_model_errors_total";

/// Alert transition direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertKind {
    Fired,
    Resolved,
}

/// One structured alert-log entry.
#[derive(Clone, Debug)]
pub struct AlertEvent {
    /// Clock-seconds of the transition.
    pub at: f64,
    pub model: String,
    /// One of [`SLO_ALERTS`].
    pub alert: &'static str,
    pub kind: AlertKind,
    /// Burn rates observed at the transition (multiples of budget).
    pub burn_fast: f64,
    pub burn_slow: f64,
}

impl AlertEvent {
    /// One-line structured rendering for the alert log.
    pub fn render(&self) -> String {
        format!(
            "t={:.1}s {} alert={} model={} burn_fast={:.2}x burn_slow={:.2}x",
            self.at,
            match self.kind {
                AlertKind::Fired => "FIRED",
                AlertKind::Resolved => "RESOLVED",
            },
            self.alert,
            self.model,
            self.burn_fast,
            self.burn_slow
        )
    }
}

struct AlertSlot {
    gauge: Gauge,
    active: bool,
}

/// Burn-rate evaluator. Create once, call [`eval_once`](Self::eval_once)
/// on a cadence (or let [`SloTask`] drive it on the clock).
pub struct SloEngine {
    cfg: ObservabilityConfig,
    registry: Registry,
    store: MetricStore,
    clock: Clock,
    slots: Mutex<BTreeMap<(String, &'static str), AlertSlot>>,
    events: Mutex<Vec<AlertEvent>>,
}

impl SloEngine {
    /// Engine over a registry (breach counting) and store (windowing).
    pub fn new(
        cfg: ObservabilityConfig,
        registry: Registry,
        store: MetricStore,
        clock: Clock,
    ) -> Self {
        let slots = cfg
            .slos
            .iter()
            .flat_map(|s| {
                SLO_ALERTS.iter().map(|&alert| {
                    let gauge = registry.gauge(
                        ALERT_GAUGE,
                        &labels(&[("alert", alert), ("model", &s.model)]),
                    );
                    gauge.set(0.0);
                    ((s.model.clone(), alert), AlertSlot { gauge, active: false })
                })
            })
            .collect();
        SloEngine {
            cfg,
            registry,
            store,
            clock,
            slots: Mutex::new(slots),
            events: Mutex::new(Vec::new()),
        }
    }

    /// Evaluate every configured SLO once at the current clock time.
    pub fn eval_once(&self) {
        let now = self.clock.now_secs();
        for slo in &self.cfg.slos {
            // Snapshot the per-model latency histogram and publish
            // good/total cumulative series so window deltas work even
            // without the background scraper.
            let h = self
                .registry
                .histogram(MODEL_LATENCY_HIST, &labels(&[("model", &slo.model)]))
                .snapshot();
            let good = count_at_or_below(&h, slo.latency_p99.as_secs_f64());
            let ok_total = h.count() as f64;
            let requests = self
                .registry
                .counter(MODEL_REQUESTS_COUNTER, &labels(&[("model", &slo.model)]))
                .get() as f64;
            let errors = self
                .registry
                .counter(MODEL_ERRORS_COUNTER, &labels(&[("model", &slo.model)]))
                .get() as f64;
            let m = &slo.model;
            self.store.push(&format!("slo_good_total{{model=\"{m}\"}}"), now, good);
            self.store.push(&format!("slo_ok_total{{model=\"{m}\"}}"), now, ok_total);
            self.store.push(&format!("slo_requests_total{{model=\"{m}\"}}"), now, requests);
            self.store.push(&format!("slo_errors_total{{model=\"{m}\"}}"), now, errors);

            let latency_burn = |w: Duration| -> Option<f64> {
                let d_ok = self.delta(&format!("slo_ok_total{{model=\"{m}\"}}"), now, w)?;
                if d_ok <= 0.0 {
                    return Some(0.0);
                }
                let d_good = self
                    .delta(&format!("slo_good_total{{model=\"{m}\"}}"), now, w)
                    .unwrap_or(0.0);
                Some(((d_ok - d_good).max(0.0) / d_ok) / LATENCY_BUDGET)
            };
            let error_burn = |w: Duration| -> Option<f64> {
                let d_req = self.delta(&format!("slo_requests_total{{model=\"{m}\"}}"), now, w)?;
                if d_req <= 0.0 {
                    return Some(0.0);
                }
                let d_err = self
                    .delta(&format!("slo_errors_total{{model=\"{m}\"}}"), now, w)
                    .unwrap_or(0.0);
                Some((d_err.max(0.0) / d_req) / slo.error_budget.max(1e-9))
            };

            self.update_alert(
                m,
                "latency_burn_rate",
                latency_burn(self.cfg.slo_fast_window),
                latency_burn(self.cfg.slo_slow_window),
                now,
            );
            self.update_alert(
                m,
                "error_budget_burn_rate",
                error_burn(self.cfg.slo_fast_window),
                error_burn(self.cfg.slo_slow_window),
                now,
            );
        }
    }

    /// Last-minus-first delta of a cumulative series over the trailing
    /// window; `None` until two points exist (no alerting on one sample).
    fn delta(&self, series: &str, now: f64, window: Duration) -> Option<f64> {
        let pts = self.store.range(series, now - window.as_secs_f64(), now);
        if pts.len() < 2 {
            return None;
        }
        Some(pts[pts.len() - 1].1 - pts[0].1)
    }

    fn update_alert(
        &self,
        model: &str,
        alert: &'static str,
        fast: Option<f64>,
        slow: Option<f64>,
        now: f64,
    ) {
        let mut slots = self.slots.lock().unwrap();
        let Some(slot) = slots.get_mut(&(model.to_string(), alert)) else {
            return;
        };
        let (Some(fast), Some(slow)) = (fast, slow) else {
            return;
        };
        let thr = self.cfg.slo_burn_threshold;
        if !slot.active && fast >= thr && slow >= thr {
            slot.active = true;
            slot.gauge.set(1.0);
            self.events.lock().unwrap().push(AlertEvent {
                at: now,
                model: model.to_string(),
                alert,
                kind: AlertKind::Fired,
                burn_fast: fast,
                burn_slow: slow,
            });
        } else if slot.active && fast < thr {
            slot.active = false;
            slot.gauge.set(0.0);
            self.events.lock().unwrap().push(AlertEvent {
                at: now,
                model: model.to_string(),
                alert,
                kind: AlertKind::Resolved,
                burn_fast: fast,
                burn_slow: slow,
            });
        }
    }

    /// Whether an alert is currently firing.
    pub fn active(&self, model: &str, alert: &str) -> bool {
        let slots = self.slots.lock().unwrap();
        SLO_ALERTS
            .iter()
            .find(|&&a| a == alert)
            .and_then(|&a| slots.get(&(model.to_string(), a)))
            .is_some_and(|s| s.active)
    }

    /// Structured alert log (transitions in evaluation order).
    pub fn events(&self) -> Vec<AlertEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Rendered alert log, one line per transition.
    pub fn render_log(&self) -> String {
        self.events
            .lock()
            .unwrap()
            .iter()
            .map(|e| e.render())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Cumulative observations at or below `target`, interpolating linearly
/// within the bucket that straddles it (same estimator family as
/// `histogram_quantile`).
fn count_at_or_below(h: &crate::util::stats::Histogram, target: f64) -> f64 {
    let bounds = h.bounds();
    let counts = h.counts();
    let mut total = 0.0;
    for (i, &c) in counts.iter().enumerate() {
        let lo = if i == 0 { 0.0 } else { bounds[i - 1] };
        if i >= bounds.len() {
            // +Inf bucket: nothing here is provably under a finite target.
            break;
        }
        let hi = bounds[i];
        if hi <= target {
            total += c as f64;
        } else if lo < target {
            total += c as f64 * ((target - lo) / (hi - lo)).clamp(0.0, 1.0);
        } else {
            break;
        }
    }
    total
}

/// Background evaluation loop on the shared clock (Scraper-style:
/// dropping the task stops and joins the thread).
pub struct SloTask {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl SloTask {
    /// Evaluate `engine` every `interval` of clock time.
    pub fn start(engine: Arc<SloEngine>, clock: Clock, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("slo-engine".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    engine.eval_once();
                    clock.sleep(interval);
                }
            })
            .expect("spawning slo engine");
        SloTask { stop, handle: Some(handle) }
    }
}

impl Drop for SloTask {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::SloConfig;

    fn test_cfg() -> ObservabilityConfig {
        ObservabilityConfig {
            trace_sample_rate: 1.0,
            trace_capacity: 1024,
            slo_fast_window: Duration::from_secs(60),
            slo_slow_window: Duration::from_secs(300),
            slo_eval_interval: Duration::from_secs(5),
            slo_burn_threshold: 10.0,
            slos: vec![SloConfig {
                model: "pn".into(),
                latency_p99: Duration::from_millis(100),
                error_budget: 0.01,
            }],
            ..ObservabilityConfig::default()
        }
    }

    fn engine() -> (SloEngine, Registry, Clock) {
        let registry = Registry::new();
        let store = MetricStore::new(Duration::from_secs(3600));
        let clock = Clock::simulated();
        let e = SloEngine::new(test_cfg(), registry.clone(), store, clock.clone());
        (e, registry, clock)
    }

    #[test]
    fn count_at_or_below_interpolates() {
        let mut h = crate::util::stats::Histogram::new(vec![0.1, 0.2, 0.4]);
        for v in [0.05, 0.15, 0.15, 0.3, 9.0] {
            h.observe(v);
        }
        assert!((count_at_or_below(&h, 0.2) - 3.0).abs() < 1e-9);
        // Halfway through the (0.2, 0.4] bucket: 3 + 0.5.
        assert!((count_at_or_below(&h, 0.3) - 3.5).abs() < 1e-9);
        // +Inf bucket observations never count as good.
        assert!(count_at_or_below(&h, 100.0) <= 4.0);
    }

    #[test]
    fn alert_fires_under_burn_and_resolves() {
        let (e, registry, clock) = engine();
        let h = registry.histogram(MODEL_LATENCY_HIST, &labels(&[("model", "pn")]));
        let reqs = registry.counter(MODEL_REQUESTS_COUNTER, &labels(&[("model", "pn")]));
        e.eval_once(); // baseline point
        // Overload: every request far over the 100ms target.
        for _ in 0..100 {
            h.observe(1.0);
            reqs.inc();
        }
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert!(e.active("pn", "latency_burn_rate"), "burn 100x must fire");
        assert!(!e.active("pn", "error_budget_burn_rate"));
        // Recovery: fast requests push windowed breach fraction down.
        for step in 0..8 {
            clock.advance(Duration::from_secs(10));
            for _ in 0..400 {
                h.observe(0.001);
                reqs.inc();
            }
            e.eval_once();
            let _ = step;
        }
        assert!(!e.active("pn", "latency_burn_rate"), "must resolve in recovery");
        let kinds: Vec<AlertKind> = e
            .events()
            .iter()
            .filter(|ev| ev.alert == "latency_burn_rate")
            .map(|ev| ev.kind)
            .collect();
        assert_eq!(kinds, vec![AlertKind::Fired, AlertKind::Resolved]);
        assert!(e.render_log().contains("FIRED"));
    }

    #[test]
    fn error_budget_alert() {
        let (e, registry, clock) = engine();
        let reqs = registry.counter(MODEL_REQUESTS_COUNTER, &labels(&[("model", "pn")]));
        let errs = registry.counter(MODEL_ERRORS_COUNTER, &labels(&[("model", "pn")]));
        e.eval_once();
        reqs.add(100);
        errs.add(50); // 50% errors on a 1% budget: burn 50x.
        clock.advance(Duration::from_secs(10));
        e.eval_once();
        assert!(e.active("pn", "error_budget_burn_rate"));
        let g = registry.gauge(
            ALERT_GAUGE,
            &labels(&[("alert", "error_budget_burn_rate"), ("model", "pn")]),
        );
        assert!((g.get() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_false_positive_at_steady_state() {
        let (e, registry, clock) = engine();
        let h = registry.histogram(MODEL_LATENCY_HIST, &labels(&[("model", "pn")]));
        let reqs = registry.counter(MODEL_REQUESTS_COUNTER, &labels(&[("model", "pn")]));
        for _ in 0..20 {
            for _ in 0..50 {
                h.observe(0.002);
                reqs.inc();
            }
            clock.advance(Duration::from_secs(5));
            e.eval_once();
        }
        assert!(e.events().is_empty(), "steady state must not page: {:?}", e.events());
    }
}
