//! Concurrency schedules: phases of (client count, duration).

use std::time::Duration;

/// One schedule phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phase {
    /// Concurrent closed-loop clients during the phase.
    pub clients: usize,
    /// Phase length in *clock* time.
    pub duration: Duration,
}

/// A piecewise-constant concurrency schedule.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    phases: Vec<Phase>,
}

impl Schedule {
    /// Empty schedule; chain [`Schedule::phase`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a phase.
    pub fn phase(mut self, clients: usize, duration: Duration) -> Self {
        self.phases.push(Phase { clients, duration });
        self
    }

    /// The paper's Fig. 2 workload: `lo` clients, step to `hi`, back to
    /// `lo`, each phase `phase_len` long.
    pub fn step_up_down(lo: usize, hi: usize, phase_len: Duration) -> Self {
        Schedule::new()
            .phase(lo, phase_len)
            .phase(hi, phase_len)
            .phase(lo, phase_len)
    }

    /// Constant concurrency.
    pub fn constant(clients: usize, duration: Duration) -> Self {
        Schedule::new().phase(clients, duration)
    }

    /// Phases in order.
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// Total schedule duration.
    pub fn total_duration(&self) -> Duration {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Peak concurrency across phases.
    pub fn max_clients(&self) -> usize {
        self.phases.iter().map(|p| p.clients).max().unwrap_or(0)
    }

    /// Client count at clock-offset `t` from schedule start (None once the
    /// schedule is over).
    pub fn clients_at(&self, t: Duration) -> Option<usize> {
        let mut acc = Duration::ZERO;
        for p in &self.phases {
            acc += p.duration;
            if t < acc {
                return Some(p.clients);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_up_down_shape() {
        let s = Schedule::step_up_down(1, 10, Duration::from_secs(60));
        assert_eq!(s.phases().len(), 3);
        assert_eq!(s.phases()[0].clients, 1);
        assert_eq!(s.phases()[1].clients, 10);
        assert_eq!(s.phases()[2].clients, 1);
        assert_eq!(s.total_duration(), Duration::from_secs(180));
        assert_eq!(s.max_clients(), 10);
    }

    #[test]
    fn clients_at_offsets() {
        let s = Schedule::step_up_down(1, 10, Duration::from_secs(10));
        assert_eq!(s.clients_at(Duration::from_secs(0)), Some(1));
        assert_eq!(s.clients_at(Duration::from_secs(9)), Some(1));
        assert_eq!(s.clients_at(Duration::from_secs(10)), Some(10));
        assert_eq!(s.clients_at(Duration::from_secs(25)), Some(1));
        assert_eq!(s.clients_at(Duration::from_secs(30)), None);
    }

    #[test]
    fn empty_schedule() {
        let s = Schedule::new();
        assert_eq!(s.total_duration(), Duration::ZERO);
        assert_eq!(s.clients_at(Duration::ZERO), None);
        assert_eq!(s.max_clients(), 0);
    }

    #[test]
    fn constant_schedule() {
        let s = Schedule::constant(4, Duration::from_secs(5));
        assert_eq!(s.phases().len(), 1);
        assert_eq!(s.clients_at(Duration::from_secs(3)), Some(4));
    }
}
