//! Load generation — the Triton `perf_analyzer` analogue (§4).
//!
//! The paper's Fig. 2/3 workload is "NVIDIA Triton Performance Analyzer
//! clients that evaluate the ParticleNet model", stepped 1 → 10 → 1
//! concurrent clients. This module reproduces that tool:
//!
//! * [`schedule`] — time-varying concurrency schedules (phases of
//!   `(clients, duration)`), including the canonical `1→10→1` step;
//! * [`generator`] — closed-loop client pools: each client owns one TCP
//!   connection to the gateway and issues requests back-to-back
//!   (optionally with think time), exactly perf_analyzer's concurrency
//!   model. Per-phase and overall latency/throughput statistics come out
//!   as [`util::stats::Summary`](crate::util::stats::Summary)s.
//! * [`generator::MixedPool`] — skewed multi-model traffic (a hot/cold
//!   model mix, weighted per request) with per-model outcome counts; the
//!   workload the modelmesh placement ablation runs.

pub mod generator;
pub mod schedule;

pub use generator::{
    ClientPool, EntryStats, MixEntry, MixedPool, MixedReport, ModelStats, PhaseReport,
    RunReport, WorkloadSpec,
};
pub use schedule::{Phase, Schedule};
