//! Closed-loop client pools (the perf_analyzer concurrency model).
//!
//! Each client = one thread = one TCP connection issuing requests
//! back-to-back: concurrency N means at most N requests in flight, and
//! client-side latency feedback throttles the offered load exactly like
//! perf_analyzer's `--concurrency-range`. The driver walks the
//! [`Schedule`] phase by phase, resizing the pool at each boundary.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::rpc::client::RpcClient;
use crate::rpc::codec::{Priority, Status};
use crate::runtime::Tensor;
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::schedule::Schedule;

/// Process-wide trace-id allocator for traced workload streams (0 is
/// the reserved "untraced" id, so allocation starts at 1).
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// What each client sends.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Model to request.
    pub model: String,
    /// Rows per request (the paper calibrates this so one GPU sustains
    /// one client but not ten).
    pub batch_rows: usize,
    /// Per-sample input shape (from the model's repository config).
    pub input_shape: Vec<usize>,
    /// Auth token ("" when the gateway has auth disabled).
    pub token: String,
    /// Priority class tagged onto every request of this stream (the
    /// workload's priority mix: run several specs/entries at different
    /// classes).
    pub priority: Priority,
    /// Pause between a response and the next request, in clock time
    /// (zero = fully closed loop).
    pub think_time: Duration,
    /// Attach a fresh trace id (sampled) to every request, so the
    /// deployment's tracer records a per-stage breakdown for this
    /// stream. Off by default: untraced load measures the no-tracing
    /// baseline.
    pub trace: bool,
}

impl WorkloadSpec {
    /// Spec with no think time, no token, `standard` priority.
    pub fn new(model: &str, batch_rows: usize, input_shape: Vec<usize>) -> Self {
        WorkloadSpec {
            model: model.to_string(),
            batch_rows,
            input_shape,
            token: String::new(),
            priority: Priority::Standard,
            think_time: Duration::ZERO,
            trace: false,
        }
    }

    /// Same spec, tagged with a priority class.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Same spec, with per-request trace propagation enabled.
    pub fn with_tracing(mut self) -> Self {
        self.trace = true;
        self
    }

    fn request_tensor(&self) -> Tensor {
        let mut shape = vec![self.batch_rows];
        shape.extend_from_slice(&self.input_shape);
        Tensor::zeros(shape)
    }
}

/// Statistics for one schedule phase.
#[derive(Clone, Debug)]
pub struct PhaseReport {
    /// Concurrency during the phase.
    pub clients: usize,
    /// Actual phase length in clock seconds.
    pub duration: f64,
    /// Per-request end-to-end latency (clock seconds).
    pub latency: Summary,
    /// Completed OK requests.
    pub ok: u64,
    /// Requests shed by the gateway (rate limited / overloaded).
    pub shed: u64,
    /// Other errors (bad request, internal, transport).
    pub errors: u64,
}

impl PhaseReport {
    /// Successful requests per clock second.
    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.ok as f64 / self.duration
        }
    }

    /// Inference rate in rows (samples) per clock second.
    pub fn row_rate(&self, rows_per_request: usize) -> f64 {
        self.throughput() * rows_per_request as f64
    }
}

/// Statistics for a whole run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub phases: Vec<PhaseReport>,
    /// Latency across all phases.
    pub overall_latency: Summary,
    pub total_ok: u64,
    pub total_shed: u64,
    pub total_errors: u64,
    /// Whole-run duration in clock seconds.
    pub duration: f64,
}

impl RunReport {
    /// Overall successful requests per clock second.
    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.total_ok as f64 / self.duration
        }
    }
}

struct PhaseCounters {
    latency: Mutex<Summary>,
    ok: AtomicU64,
    shed: AtomicU64,
    errors: AtomicU64,
}

impl PhaseCounters {
    fn new() -> Self {
        PhaseCounters {
            latency: Mutex::new(Summary::new()),
            ok: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }
    }
}

/// The load generator.
pub struct ClientPool {
    addr: String,
    spec: WorkloadSpec,
    clock: Clock,
}

impl ClientPool {
    /// Pool targeting `addr` (the gateway endpoint).
    pub fn new(addr: &str, spec: WorkloadSpec, clock: Clock) -> Self {
        ClientPool { addr: addr.to_string(), spec, clock }
    }

    /// Run the schedule to completion; blocks the calling thread.
    ///
    /// `on_phase` fires at each phase boundary with (index, clients) —
    /// experiments use it to annotate timelines.
    pub fn run(&self, schedule: &Schedule) -> RunReport {
        self.run_with(schedule, |_, _| {})
    }

    /// [`ClientPool::run`] with a phase-boundary callback.
    pub fn run_with<F: FnMut(usize, usize)>(
        &self,
        schedule: &Schedule,
        mut on_phase: F,
    ) -> RunReport {
        let run_start = self.clock.now_secs();
        let mut phases = Vec::new();
        let mut overall = Summary::new();
        let (mut total_ok, mut total_shed, mut total_errors) = (0u64, 0u64, 0u64);

        for (idx, phase) in schedule.phases().iter().enumerate() {
            on_phase(idx, phase.clients);
            let counters = Arc::new(PhaseCounters::new());
            let stop = Arc::new(AtomicBool::new(false));
            let phase_start = self.clock.now_secs();

            let mut handles = Vec::with_capacity(phase.clients);
            for c in 0..phase.clients {
                let addr = self.addr.clone();
                let spec = self.spec.clone();
                let clock = self.clock.clone();
                let counters = Arc::clone(&counters);
                let stop = Arc::clone(&stop);
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("client-{idx}-{c}"))
                        .spawn(move || client_loop(&addr, &spec, &clock, &counters, &stop))
                        .expect("spawning client"),
                );
            }

            self.clock.sleep(phase.duration);
            stop.store(true, Ordering::SeqCst);
            for h in handles {
                let _ = h.join();
            }

            let duration = self.clock.now_secs() - phase_start;
            let latency = counters.latency.lock().unwrap().clone();
            overall.merge(&latency);
            let report = PhaseReport {
                clients: phase.clients,
                duration,
                latency,
                ok: counters.ok.load(Ordering::SeqCst),
                shed: counters.shed.load(Ordering::SeqCst),
                errors: counters.errors.load(Ordering::SeqCst),
            };
            total_ok += report.ok;
            total_shed += report.shed;
            total_errors += report.errors;
            phases.push(report);
        }

        RunReport {
            phases,
            overall_latency: overall,
            total_ok,
            total_shed,
            total_errors,
            duration: self.clock.now_secs() - run_start,
        }
    }
}

/// One model's share of a mixed workload.
#[derive(Clone, Debug)]
pub struct MixEntry {
    /// What to send for this model.
    pub spec: WorkloadSpec,
    /// Relative traffic weight (need not sum to 1).
    pub weight: f64,
}

/// Per-model statistics from a mixed run.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    pub ok: u64,
    pub shed: u64,
    pub errors: u64,
}

/// Per-entry statistics from a mixed run — one row per [`MixEntry`], so
/// streams sharing a model but differing in priority (or shape) stay
/// separable, each with its own latency summary.
#[derive(Clone, Debug)]
pub struct EntryStats {
    pub model: String,
    pub priority: Priority,
    pub ok: u64,
    pub shed: u64,
    pub errors: u64,
    /// End-to-end latency of this entry's completed requests.
    pub latency: Summary,
}

/// Statistics for a whole mixed run.
#[derive(Clone, Debug)]
pub struct MixedReport {
    /// Per-model outcome counts, keyed by model name.
    pub per_model: BTreeMap<String, ModelStats>,
    /// Per-entry outcome counts + latency, in [`MixEntry`] order.
    pub per_entry: Vec<EntryStats>,
    /// End-to-end latency across all models.
    pub overall_latency: Summary,
    /// Whole-run duration in clock seconds.
    pub duration: f64,
}

impl MixedReport {
    /// Completed OK requests across models.
    pub fn total_ok(&self) -> u64 {
        self.per_model.values().map(|s| s.ok).sum()
    }

    /// Shed (rate-limited / overloaded) requests across models.
    pub fn total_shed(&self) -> u64 {
        self.per_model.values().map(|s| s.shed).sum()
    }

    /// Other errors across models.
    pub fn total_errors(&self) -> u64 {
        self.per_model.values().map(|s| s.errors).sum()
    }
}

struct EntryCounters {
    ok: AtomicU64,
    shed: AtomicU64,
    errors: AtomicU64,
    latency: Mutex<Summary>,
}

struct MixCounters {
    latency: Mutex<Summary>,
    /// One counter set per mix entry.
    per_entry: Vec<EntryCounters>,
}

/// Skewed multi-model load generator: each closed-loop client picks the
/// model of its next request by weight, producing the hot/cold traffic
/// mix the modelmesh placement controller reacts to.
pub struct MixedPool {
    addr: String,
    entries: Vec<MixEntry>,
    clock: Clock,
    seed: u64,
}

impl MixedPool {
    /// Pool targeting `addr` with the given traffic mix. All entries
    /// must share one auth token: clients hold a single connection to
    /// the gateway, and the connection's token is what every request
    /// rides on.
    pub fn new(addr: &str, entries: Vec<MixEntry>, clock: Clock, seed: u64) -> Self {
        assert!(!entries.is_empty(), "mixed pool needs at least one entry");
        assert!(
            entries.iter().all(|e| e.weight > 0.0),
            "mix weights must be positive"
        );
        assert!(
            entries.iter().all(|e| e.spec.token == entries[0].spec.token),
            "mixed pool entries must share one auth token"
        );
        MixedPool { addr: addr.to_string(), entries, clock, seed }
    }

    /// The canonical two-model skew: `hot_fraction` of requests go to
    /// `hot`, the rest to `cold`.
    pub fn hot_cold(
        addr: &str,
        hot: WorkloadSpec,
        cold: WorkloadSpec,
        hot_fraction: f64,
        clock: Clock,
        seed: u64,
    ) -> Self {
        assert!((0.0..1.0).contains(&hot_fraction) && hot_fraction > 0.0);
        Self::new(
            addr,
            vec![
                MixEntry { spec: hot, weight: hot_fraction },
                MixEntry { spec: cold, weight: 1.0 - hot_fraction },
            ],
            clock,
            seed,
        )
    }

    /// Run the schedule to completion; blocks the calling thread.
    pub fn run(&self, schedule: &Schedule) -> MixedReport {
        let run_start = self.clock.now_secs();
        let counters = Arc::new(MixCounters {
            latency: Mutex::new(Summary::new()),
            per_entry: self
                .entries
                .iter()
                .map(|_| EntryCounters {
                    ok: AtomicU64::new(0),
                    shed: AtomicU64::new(0),
                    errors: AtomicU64::new(0),
                    latency: Mutex::new(Summary::new()),
                })
                .collect(),
        });

        for (idx, phase) in schedule.phases().iter().enumerate() {
            let stop = Arc::new(AtomicBool::new(false));
            let mut handles = Vec::with_capacity(phase.clients);
            for c in 0..phase.clients {
                let addr = self.addr.clone();
                let entries = self.entries.clone();
                let clock = self.clock.clone();
                let counters = Arc::clone(&counters);
                let stop = Arc::clone(&stop);
                let seed = self
                    .seed
                    .wrapping_add((idx as u64) << 32)
                    .wrapping_add(c as u64 + 1);
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("mix-{idx}-{c}"))
                        .spawn(move || {
                            mixed_client_loop(&addr, &entries, &clock, &counters, &stop, seed)
                        })
                        .expect("spawning mixed client"),
                );
            }
            self.clock.sleep(phase.duration);
            stop.store(true, Ordering::SeqCst);
            for h in handles {
                let _ = h.join();
            }
        }

        // Per-entry rows first (priority-separable), then merged by
        // model name: two entries may target the same model (e.g. the
        // same model at different priorities or shapes).
        let mut per_entry = Vec::with_capacity(self.entries.len());
        let mut per_model: BTreeMap<String, ModelStats> = BTreeMap::new();
        for (e, c) in self.entries.iter().zip(counters.per_entry.iter()) {
            let entry = EntryStats {
                model: e.spec.model.clone(),
                priority: e.spec.priority,
                ok: c.ok.load(Ordering::SeqCst),
                shed: c.shed.load(Ordering::SeqCst),
                errors: c.errors.load(Ordering::SeqCst),
                latency: c.latency.lock().unwrap().clone(),
            };
            let stats = per_model.entry(e.spec.model.clone()).or_default();
            stats.ok += entry.ok;
            stats.shed += entry.shed;
            stats.errors += entry.errors;
            per_entry.push(entry);
        }
        MixedReport {
            per_model,
            per_entry,
            overall_latency: counters.latency.lock().unwrap().clone(),
            duration: self.clock.now_secs() - run_start,
        }
    }
}

fn mixed_client_loop(
    addr: &str,
    entries: &[MixEntry],
    clock: &Clock,
    counters: &MixCounters,
    stop: &AtomicBool,
    seed: u64,
) {
    let mut client = loop {
        match RpcClient::connect(addr) {
            Ok(c) => break c.with_token(&entries[0].spec.token),
            Err(_) if !stop.load(Ordering::SeqCst) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => return,
        }
    };
    let inputs: Vec<Tensor> = entries.iter().map(|e| e.spec.request_tensor()).collect();
    let total_weight: f64 = entries.iter().map(|e| e.weight).sum();
    let mut rng = Rng::seeded(seed);

    while !stop.load(Ordering::SeqCst) {
        // Weighted pick of the next request's model.
        let mut roll = rng.range_f64(0.0, total_weight);
        let mut idx = 0;
        for (i, e) in entries.iter().enumerate() {
            idx = i;
            if roll < e.weight {
                break;
            }
            roll -= e.weight;
        }
        let entry = &entries[idx];
        let c = &counters.per_entry[idx];

        let t0 = clock.now_secs();
        match client.infer_prio(&entry.spec.model, inputs[idx].clone(), entry.spec.priority) {
            Ok(resp) => match resp.status {
                Status::Ok => {
                    let dt = clock.now_secs() - t0;
                    counters.latency.lock().unwrap().observe(dt);
                    c.latency.lock().unwrap().observe(dt);
                    c.ok.fetch_add(1, Ordering::Relaxed);
                }
                Status::RateLimited | Status::Overloaded => {
                    c.shed.fetch_add(1, Ordering::Relaxed);
                    clock.sleep(Duration::from_millis(10));
                }
                _ => {
                    c.errors.fetch_add(1, Ordering::Relaxed);
                }
            },
            Err(_) => {
                c.errors.fetch_add(1, Ordering::Relaxed);
                // reconnect with the pool's (shared) token
                match RpcClient::connect(addr) {
                    Ok(fresh) => client = fresh.with_token(&entries[0].spec.token),
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            }
        }
        if !entry.spec.think_time.is_zero() {
            clock.sleep(entry.spec.think_time);
        }
    }
}

fn client_loop(
    addr: &str,
    spec: &WorkloadSpec,
    clock: &Clock,
    counters: &PhaseCounters,
    stop: &AtomicBool,
) {
    // Retry the initial connect briefly: at experiment start the gateway
    // may bind a moment after the pool launches.
    let mut client = loop {
        match RpcClient::connect(addr) {
            Ok(c) => break c.with_token(&spec.token).with_priority(spec.priority),
            Err(_) if !stop.load(Ordering::SeqCst) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => return,
        }
    };
    let input = spec.request_tensor();

    while !stop.load(Ordering::SeqCst) {
        if spec.trace {
            client.trace_id = NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed);
        }
        let t0 = clock.now_secs();
        match client.infer(&spec.model, input.clone()) {
            Ok(resp) => {
                let dt = clock.now_secs() - t0;
                match resp.status {
                    Status::Ok => {
                        counters.latency.lock().unwrap().observe(dt);
                        counters.ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Status::RateLimited | Status::Overloaded => {
                        counters.shed.fetch_add(1, Ordering::Relaxed);
                        // brief backoff so a shedding gateway is not
                        // hammered in a tight loop
                        clock.sleep(Duration::from_millis(10));
                    }
                    _ => {
                        counters.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(_) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                // transport error: reconnect
                match RpcClient::connect(addr) {
                    Ok(c) => client = c.with_token(&spec.token).with_priority(spec.priority),
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            }
        }
        if !spec.think_time.is_zero() {
            clock.sleep(spec.think_time);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, GatewayConfig, ModelConfig, ServiceModelConfig};
    use crate::gateway::Gateway;
    use crate::metrics::Registry;
    use crate::server::{Instance, ModelRepository};
    use crate::telemetry::Tracer;
    use once_cell::sync::Lazy;
    use std::sync::RwLock;

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn stack(n: usize) -> (Gateway, Vec<Arc<Instance>>, Clock) {
        let clock = Clock::real();
        let registry = Registry::new();
        let instances: Vec<Arc<Instance>> = (0..n)
            .map(|i| {
                let inst = Instance::start_with_mode(
                    &format!("wl-{i}"),
                    Arc::clone(&REPO),
                    &[ModelConfig {
                        name: "icecube_cnn".into(),
                        max_queue_delay: Duration::from_millis(1),
                        preferred_batch: 8,
                        service_model: ServiceModelConfig {
                            base: Duration::from_millis(2),
                            per_row: Duration::from_micros(100),
                        },
                        load_delay: None,
                        backends: Vec::new(),
                        ..ModelConfig::default()
                    }],
                    clock.clone(),
                    registry.clone(),
                    64,
                    5.0,
                    ExecutionMode::Simulated,
                );
                inst.mark_ready();
                inst
            })
            .collect();
        let endpoints = Arc::new(RwLock::new(instances.clone()));
        let gateway = Gateway::start(
            &GatewayConfig::default(),
            endpoints,
            clock.clone(),
            registry,
            Tracer::disabled(),
            None,
        )
        .unwrap();
        (gateway, instances, clock)
    }

    #[test]
    fn constant_load_served() {
        let (gateway, instances, clock) = stack(2);
        let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let report = pool.run(&Schedule::constant(2, Duration::from_millis(300)));
        assert_eq!(report.phases.len(), 1);
        assert!(report.total_ok > 10, "ok={}", report.total_ok);
        assert_eq!(report.total_errors, 0);
        assert!(report.throughput() > 0.0);
        assert!(report.overall_latency.mean() > 0.0);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn step_schedule_reports_per_phase() {
        let (gateway, instances, clock) = stack(1);
        let spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let mut boundaries = Vec::new();
        let report = pool.run_with(
            &Schedule::step_up_down(1, 4, Duration::from_millis(200)),
            |i, c| boundaries.push((i, c)),
        );
        assert_eq!(boundaries, vec![(0, 1), (1, 4), (2, 1)]);
        assert_eq!(report.phases.len(), 3);
        // the 4-client phase must have completed more requests than the
        // 1-client phases (one simulated GPU, but closed loop means more
        // offered load -> more batched work completed)
        assert!(report.phases[1].ok > 0);
        // phase durations roughly as scheduled
        assert!((report.phases[0].duration - 0.2).abs() < 0.15);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn think_time_reduces_offered_load() {
        let (gateway, instances, clock) = stack(1);
        let mut spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
        let fast_pool = ClientPool::new(&gateway.addr().to_string(), spec.clone(), clock.clone());
        let fast = fast_pool.run(&Schedule::constant(1, Duration::from_millis(250)));
        spec.think_time = Duration::from_millis(50);
        let slow_pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let slow = slow_pool.run(&Schedule::constant(1, Duration::from_millis(250)));
        assert!(
            fast.total_ok > slow.total_ok,
            "fast {} vs slow {}",
            fast.total_ok,
            slow.total_ok
        );
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn mixed_pool_skews_traffic() {
        let (gateway, instances, clock) = stack(2);
        let hot = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
        // Cold model does not exist: its share shows up as errors, which
        // also proves per-model accounting separates the streams.
        let cold = WorkloadSpec::new("missing_model", 1, vec![16, 16, 3]);
        let pool = MixedPool::hot_cold(
            &gateway.addr().to_string(),
            hot,
            cold,
            0.8,
            clock,
            42,
        );
        let report = pool.run(&Schedule::constant(2, Duration::from_millis(400)));
        let hot_stats = &report.per_model["icecube_cnn"];
        let cold_stats = &report.per_model["missing_model"];
        assert!(hot_stats.ok > 0, "hot model never served");
        assert_eq!(hot_stats.errors, 0);
        assert_eq!(cold_stats.ok, 0);
        assert!(cold_stats.errors > 0, "cold model errors not recorded");
        // 80/20 skew: the hot stream clearly dominates.
        assert!(
            hot_stats.ok + hot_stats.errors > cold_stats.ok + cold_stats.errors,
            "skew not applied: hot={hot_stats:?} cold={cold_stats:?}"
        );
        assert_eq!(report.total_ok(), hot_stats.ok);
        assert!(report.duration > 0.0);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn mixed_pool_separates_priority_streams() {
        let (gateway, instances, clock) = stack(2);
        let critical = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3])
            .with_priority(Priority::Critical);
        let bulk = WorkloadSpec::new("icecube_cnn", 4, vec![16, 16, 3])
            .with_priority(Priority::Bulk);
        let pool = MixedPool::new(
            &gateway.addr().to_string(),
            vec![
                MixEntry { spec: critical, weight: 0.5 },
                MixEntry { spec: bulk, weight: 0.5 },
            ],
            clock,
            7,
        );
        let report = pool.run(&Schedule::constant(2, Duration::from_millis(400)));
        // Same model, two priority streams: per_entry keeps them apart,
        // each with its own latency summary.
        assert_eq!(report.per_entry.len(), 2);
        let crit = &report.per_entry[0];
        let blk = &report.per_entry[1];
        assert_eq!(crit.priority, Priority::Critical);
        assert_eq!(blk.priority, Priority::Bulk);
        assert!(crit.ok > 0, "critical stream never served");
        assert!(blk.ok > 0, "bulk stream never served");
        assert_eq!(crit.latency.count(), crit.ok);
        assert_eq!(blk.latency.count(), blk.ok);
        // The per-model merge still folds both streams into one row.
        assert_eq!(report.per_model["icecube_cnn"].ok, crit.ok + blk.ok);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn errors_counted_not_fatal() {
        let (gateway, instances, clock) = stack(1);
        // wrong model name -> ModelNotFound counted as error
        let spec = WorkloadSpec::new("not_a_model", 1, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let report = pool.run(&Schedule::constant(1, Duration::from_millis(150)));
        assert_eq!(report.total_ok, 0);
        assert!(report.total_errors > 0);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }
}
