//! Closed-loop client pools (the perf_analyzer concurrency model).
//!
//! Each client = one thread = one TCP connection issuing requests
//! back-to-back: concurrency N means at most N requests in flight, and
//! client-side latency feedback throttles the offered load exactly like
//! perf_analyzer's `--concurrency-range`. The driver walks the
//! [`Schedule`] phase by phase, resizing the pool at each boundary.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::rpc::client::RpcClient;
use crate::rpc::codec::Status;
use crate::runtime::Tensor;
use crate::util::clock::Clock;
use crate::util::stats::Summary;

use super::schedule::Schedule;

/// What each client sends.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Model to request.
    pub model: String,
    /// Rows per request (the paper calibrates this so one GPU sustains
    /// one client but not ten).
    pub batch_rows: usize,
    /// Per-sample input shape (from the model's repository config).
    pub input_shape: Vec<usize>,
    /// Auth token ("" when the gateway has auth disabled).
    pub token: String,
    /// Pause between a response and the next request, in clock time
    /// (zero = fully closed loop).
    pub think_time: Duration,
}

impl WorkloadSpec {
    /// Spec with no think time and no token.
    pub fn new(model: &str, batch_rows: usize, input_shape: Vec<usize>) -> Self {
        WorkloadSpec {
            model: model.to_string(),
            batch_rows,
            input_shape,
            token: String::new(),
            think_time: Duration::ZERO,
        }
    }

    fn request_tensor(&self) -> Tensor {
        let mut shape = vec![self.batch_rows];
        shape.extend_from_slice(&self.input_shape);
        Tensor::zeros(shape)
    }
}

/// Statistics for one schedule phase.
#[derive(Clone, Debug)]
pub struct PhaseReport {
    /// Concurrency during the phase.
    pub clients: usize,
    /// Actual phase length in clock seconds.
    pub duration: f64,
    /// Per-request end-to-end latency (clock seconds).
    pub latency: Summary,
    /// Completed OK requests.
    pub ok: u64,
    /// Requests shed by the gateway (rate limited / overloaded).
    pub shed: u64,
    /// Other errors (bad request, internal, transport).
    pub errors: u64,
}

impl PhaseReport {
    /// Successful requests per clock second.
    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.ok as f64 / self.duration
        }
    }

    /// Inference rate in rows (samples) per clock second.
    pub fn row_rate(&self, rows_per_request: usize) -> f64 {
        self.throughput() * rows_per_request as f64
    }
}

/// Statistics for a whole run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub phases: Vec<PhaseReport>,
    /// Latency across all phases.
    pub overall_latency: Summary,
    pub total_ok: u64,
    pub total_shed: u64,
    pub total_errors: u64,
    /// Whole-run duration in clock seconds.
    pub duration: f64,
}

impl RunReport {
    /// Overall successful requests per clock second.
    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.total_ok as f64 / self.duration
        }
    }
}

struct PhaseCounters {
    latency: Mutex<Summary>,
    ok: AtomicU64,
    shed: AtomicU64,
    errors: AtomicU64,
}

impl PhaseCounters {
    fn new() -> Self {
        PhaseCounters {
            latency: Mutex::new(Summary::new()),
            ok: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }
    }
}

/// The load generator.
pub struct ClientPool {
    addr: String,
    spec: WorkloadSpec,
    clock: Clock,
}

impl ClientPool {
    /// Pool targeting `addr` (the gateway endpoint).
    pub fn new(addr: &str, spec: WorkloadSpec, clock: Clock) -> Self {
        ClientPool { addr: addr.to_string(), spec, clock }
    }

    /// Run the schedule to completion; blocks the calling thread.
    ///
    /// `on_phase` fires at each phase boundary with (index, clients) —
    /// experiments use it to annotate timelines.
    pub fn run(&self, schedule: &Schedule) -> RunReport {
        self.run_with(schedule, |_, _| {})
    }

    /// [`ClientPool::run`] with a phase-boundary callback.
    pub fn run_with<F: FnMut(usize, usize)>(
        &self,
        schedule: &Schedule,
        mut on_phase: F,
    ) -> RunReport {
        let run_start = self.clock.now_secs();
        let mut phases = Vec::new();
        let mut overall = Summary::new();
        let (mut total_ok, mut total_shed, mut total_errors) = (0u64, 0u64, 0u64);

        for (idx, phase) in schedule.phases().iter().enumerate() {
            on_phase(idx, phase.clients);
            let counters = Arc::new(PhaseCounters::new());
            let stop = Arc::new(AtomicBool::new(false));
            let phase_start = self.clock.now_secs();

            let mut handles = Vec::with_capacity(phase.clients);
            for c in 0..phase.clients {
                let addr = self.addr.clone();
                let spec = self.spec.clone();
                let clock = self.clock.clone();
                let counters = Arc::clone(&counters);
                let stop = Arc::clone(&stop);
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("client-{idx}-{c}"))
                        .spawn(move || client_loop(&addr, &spec, &clock, &counters, &stop))
                        .expect("spawning client"),
                );
            }

            self.clock.sleep(phase.duration);
            stop.store(true, Ordering::SeqCst);
            for h in handles {
                let _ = h.join();
            }

            let duration = self.clock.now_secs() - phase_start;
            let latency = counters.latency.lock().unwrap().clone();
            overall.merge(&latency);
            let report = PhaseReport {
                clients: phase.clients,
                duration,
                latency,
                ok: counters.ok.load(Ordering::SeqCst),
                shed: counters.shed.load(Ordering::SeqCst),
                errors: counters.errors.load(Ordering::SeqCst),
            };
            total_ok += report.ok;
            total_shed += report.shed;
            total_errors += report.errors;
            phases.push(report);
        }

        RunReport {
            phases,
            overall_latency: overall,
            total_ok,
            total_shed,
            total_errors,
            duration: self.clock.now_secs() - run_start,
        }
    }
}

fn client_loop(
    addr: &str,
    spec: &WorkloadSpec,
    clock: &Clock,
    counters: &PhaseCounters,
    stop: &AtomicBool,
) {
    // Retry the initial connect briefly: at experiment start the gateway
    // may bind a moment after the pool launches.
    let mut client = loop {
        match RpcClient::connect(addr) {
            Ok(c) => break c.with_token(&spec.token),
            Err(_) if !stop.load(Ordering::SeqCst) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => return,
        }
    };
    let input = spec.request_tensor();

    while !stop.load(Ordering::SeqCst) {
        let t0 = clock.now_secs();
        match client.infer(&spec.model, input.clone()) {
            Ok(resp) => {
                let dt = clock.now_secs() - t0;
                match resp.status {
                    Status::Ok => {
                        counters.latency.lock().unwrap().observe(dt);
                        counters.ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Status::RateLimited | Status::Overloaded => {
                        counters.shed.fetch_add(1, Ordering::Relaxed);
                        // brief backoff so a shedding gateway is not
                        // hammered in a tight loop
                        clock.sleep(Duration::from_millis(10));
                    }
                    _ => {
                        counters.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(_) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                // transport error: reconnect
                match RpcClient::connect(addr) {
                    Ok(c) => client = c.with_token(&spec.token),
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            }
        }
        if !spec.think_time.is_zero() {
            clock.sleep(spec.think_time);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecutionMode, GatewayConfig, ModelConfig, ServiceModelConfig};
    use crate::gateway::Gateway;
    use crate::metrics::Registry;
    use crate::server::{Instance, ModelRepository};
    use crate::telemetry::Tracer;
    use once_cell::sync::Lazy;
    use std::sync::RwLock;

    static REPO: Lazy<Arc<ModelRepository>> = Lazy::new(|| {
        Arc::new(
            ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &["icecube_cnn".into()],
            )
            .unwrap(),
        )
    });

    fn stack(n: usize) -> (Gateway, Vec<Arc<Instance>>, Clock) {
        let clock = Clock::real();
        let registry = Registry::new();
        let instances: Vec<Arc<Instance>> = (0..n)
            .map(|i| {
                let inst = Instance::start_with_mode(
                    &format!("wl-{i}"),
                    Arc::clone(&REPO),
                    &[ModelConfig {
                        name: "icecube_cnn".into(),
                        max_queue_delay: Duration::from_millis(1),
                        preferred_batch: 8,
                        service_model: ServiceModelConfig {
                            base: Duration::from_millis(2),
                            per_row: Duration::from_micros(100),
                        },
                    }],
                    clock.clone(),
                    registry.clone(),
                    64,
                    5.0,
                    ExecutionMode::Simulated,
                );
                inst.mark_ready();
                inst
            })
            .collect();
        let endpoints = Arc::new(RwLock::new(instances.clone()));
        let gateway = Gateway::start(
            &GatewayConfig::default(),
            endpoints,
            clock.clone(),
            registry,
            Tracer::disabled(),
            None,
        )
        .unwrap();
        (gateway, instances, clock)
    }

    #[test]
    fn constant_load_served() {
        let (gateway, instances, clock) = stack(2);
        let spec = WorkloadSpec::new("icecube_cnn", 2, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let report = pool.run(&Schedule::constant(2, Duration::from_millis(300)));
        assert_eq!(report.phases.len(), 1);
        assert!(report.total_ok > 10, "ok={}", report.total_ok);
        assert_eq!(report.total_errors, 0);
        assert!(report.throughput() > 0.0);
        assert!(report.overall_latency.mean() > 0.0);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn step_schedule_reports_per_phase() {
        let (gateway, instances, clock) = stack(1);
        let spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let mut boundaries = Vec::new();
        let report = pool.run_with(
            &Schedule::step_up_down(1, 4, Duration::from_millis(200)),
            |i, c| boundaries.push((i, c)),
        );
        assert_eq!(boundaries, vec![(0, 1), (1, 4), (2, 1)]);
        assert_eq!(report.phases.len(), 3);
        // the 4-client phase must have completed more requests than the
        // 1-client phases (one simulated GPU, but closed loop means more
        // offered load -> more batched work completed)
        assert!(report.phases[1].ok > 0);
        // phase durations roughly as scheduled
        assert!((report.phases[0].duration - 0.2).abs() < 0.15);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn think_time_reduces_offered_load() {
        let (gateway, instances, clock) = stack(1);
        let mut spec = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
        let fast_pool = ClientPool::new(&gateway.addr().to_string(), spec.clone(), clock.clone());
        let fast = fast_pool.run(&Schedule::constant(1, Duration::from_millis(250)));
        spec.think_time = Duration::from_millis(50);
        let slow_pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let slow = slow_pool.run(&Schedule::constant(1, Duration::from_millis(250)));
        assert!(
            fast.total_ok > slow.total_ok,
            "fast {} vs slow {}",
            fast.total_ok,
            slow.total_ok
        );
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }

    #[test]
    fn errors_counted_not_fatal() {
        let (gateway, instances, clock) = stack(1);
        // wrong model name -> ModelNotFound counted as error
        let spec = WorkloadSpec::new("not_a_model", 1, vec![16, 16, 3]);
        let pool = ClientPool::new(&gateway.addr().to_string(), spec, clock);
        let report = pool.run(&Schedule::constant(1, Duration::from_millis(150)));
        assert_eq!(report.total_ok, 0);
        assert!(report.total_errors > 0);
        gateway.shutdown();
        for i in instances {
            i.stop();
        }
    }
}
