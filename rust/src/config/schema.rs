//! Typed deployment configuration — the `values.yaml` schema.
//!
//! Every knob the paper's Helm chart exposes has an analogue here:
//! inference servers (Triton §2.1), the gateway (Envoy §2.2: load
//! balancing, rate limiting, token auth), monitoring (Prometheus §2.3),
//! autoscaling (KEDA §2.4) and the cluster substrate (Kubernetes §2).
//! Unknown keys are *rejected* (typo protection), missing keys fall back
//! to documented defaults, and [`DeploymentConfig::validate`] enforces
//! cross-field invariants.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::yaml::{self, Value};
use crate::rpc::codec::Priority;

/// Wire/config names of every known inference backend, preference-list
/// order-independent. The single source of truth shared by config
/// validation (`server.models[].backends`, `engines.default_backend`),
/// the engine registry ([`crate::engine::BackendRegistry`]) and the
/// per-(model, backend) metrics label sets.
pub const BACKEND_NAMES: &[&str] = &["pjrt", "onnx-sim"];

/// Load-balancing policies the gateway supports (Envoy's menu, §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LbPolicy {
    /// Cycle through ready instances.
    RoundRobin,
    /// Fewest in-flight requests.
    LeastConnection,
    /// Lowest busy-fraction over the metrics window.
    UtilizationAware,
    /// Uniform random (baseline for the ablation bench).
    Random,
}

impl LbPolicy {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round_robin" => LbPolicy::RoundRobin,
            "least_connection" => LbPolicy::LeastConnection,
            "utilization_aware" => LbPolicy::UtilizationAware,
            "random" => LbPolicy::Random,
            other => bail!(
                "unknown lb policy '{other}' (expected round_robin, \
                 least_connection, utilization_aware or random)"
            ),
        })
    }

    /// Canonical config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "round_robin",
            LbPolicy::LeastConnection => "least_connection",
            LbPolicy::UtilizationAware => "utilization_aware",
            LbPolicy::Random => "random",
        }
    }
}

/// How instances execute batches (see DESIGN.md §Substitutions #3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Run the real AOT-compiled model through PJRT (the default). Latency
    /// and utilization reflect actual CPU execution of the real numerics.
    Real,
    /// Sleep a calibrated per-batch service time instead of executing
    /// (outputs are zeros). Used by the Fig. 2/3 scaling experiments,
    /// where "GPU speed" must be a T4 model rather than whatever CPU the
    /// harness happens to run on — the queueing/batching/routing code
    /// path is identical.
    Simulated,
}

impl ExecutionMode {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "real" => ExecutionMode::Real,
            "simulated" => ExecutionMode::Simulated,
            other => bail!("unknown execution mode '{other}' (expected real or simulated)"),
        })
    }

    /// Canonical config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            ExecutionMode::Real => "real",
            ExecutionMode::Simulated => "simulated",
        }
    }
}

/// How an instance's batcher admits queued requests into batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// Strict arrival order: the batcher always serves the model of the
    /// globally oldest queued request, waiting out that model's batching
    /// window even while other models have full batches ready. The
    /// pre-affinity behavior, kept as the ablation baseline.
    Fifo,
    /// Model-affinity admission (the default): requests are grouped into
    /// per-(instance, model) queues and the batcher serves whichever
    /// model has a full batch ready, falling back to deadline order, so
    /// a cold model's half-empty batching window never blocks a hot
    /// model's ready batch.
    #[default]
    Affinity,
}

impl BatchMode {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fifo" => BatchMode::Fifo,
            "affinity" => BatchMode::Affinity,
            other => bail!("unknown batch mode '{other}' (expected fifo or affinity)"),
        })
    }

    /// Canonical config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            BatchMode::Fifo => "fifo",
            BatchMode::Affinity => "affinity",
        }
    }
}

/// Linear per-batch service-time model for simulated execution:
/// `service(batch) = base + per_row * rows`. Defaults approximate an
/// NVIDIA T4 running ParticleNet (the paper's Fig. 2/3 configuration).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServiceModelConfig {
    /// Fixed per-batch launch overhead.
    pub base: Duration,
    /// Marginal cost per batched sample.
    pub per_row: Duration,
}

impl Default for ServiceModelConfig {
    fn default() -> Self {
        ServiceModelConfig {
            base: Duration::from_millis(5),
            per_row: Duration::from_micros(1500),
        }
    }
}

impl ServiceModelConfig {
    /// Service time for a batch of `rows` samples, in seconds.
    pub fn service_secs(&self, rows: usize) -> f64 {
        self.base.as_secs_f64() + self.per_row.as_secs_f64() * rows as f64
    }
}

/// One served model (a Triton model-repository entry).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Repository directory name under `artifacts/`.
    pub name: String,
    /// Dynamic-batching window: how long the batcher may hold requests
    /// while accumulating a batch.
    pub max_queue_delay: Duration,
    /// Cap on the batch the batcher will form (further capped by the
    /// largest compiled artifact).
    pub preferred_batch: usize,
    /// Service-time model used when `server.execution: simulated`.
    pub service_model: ServiceModelConfig,
    /// Per-model override of `model_placement.load_delay`: the simulated
    /// time a placement load of this model spends in `Loading` before the
    /// replica turns warm. `None` inherits the global default.
    pub load_delay: Option<Duration>,
    /// Backend preference list for this model (see [`BACKEND_NAMES`]).
    /// Empty = the default preference (`engines.default_backend` first,
    /// then every other known backend). A non-empty list is exclusive:
    /// the model is *only* ever served by the named backends, so e.g.
    /// `backends: [onnx-sim]` pins a model to CPU-capable pods.
    pub backends: Vec<String>,
    /// Registered versions of this model (Triton's versioned repository
    /// entries). Empty = the model is served unversioned under its bare
    /// name. Non-empty expands the deployment catalog to `name@vN`
    /// entries sharing the base model's weights.
    pub versions: Vec<VersionSpec>,
    /// The version unversioned client traffic lands on. `None` defaults
    /// to the first listed version.
    pub incumbent: Option<u32>,
    /// Active canary split: `weight` of unversioned traffic routes to
    /// `version` instead of the incumbent.
    pub canary: Option<CanaryConfig>,
    /// Operator override: pin ALL unversioned traffic to this version,
    /// disabling default/canary routing (the rollback escape hatch).
    pub pinned_version: Option<u32>,
}

/// One registered model version (`server.models[].versions[]`). A YAML
/// list item may be a bare version number or a map with knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VersionSpec {
    /// Version number; served as `name@vN`.
    pub version: u32,
    /// Simulated service-time multiplier relative to the base model
    /// (experiment knob: a poisoned canary is a version with a large
    /// slowdown). 1.0 = identical to the base.
    pub slowdown: f64,
}

impl Default for VersionSpec {
    fn default() -> Self {
        VersionSpec { version: 1, slowdown: 1.0 }
    }
}

/// Canary split for one model (`server.models[].canary`).
#[derive(Clone, Debug, PartialEq)]
pub struct CanaryConfig {
    /// The version receiving canary traffic (must be registered and
    /// distinct from the incumbent).
    pub version: u32,
    /// Fraction of unversioned traffic routed to the canary, in (0, 1).
    /// With a `ramp`, this is the *starting* weight (the first stage).
    pub weight: f64,
    /// Optional staged weight ramp (e.g. `[0.01, 0.1, 0.5]`): the split
    /// starts at the first stage and advances to the next one every
    /// `ramp_interval` — but only while the auto-rollback evaluator
    /// stays quiet for the model. Stages must be strictly increasing,
    /// each in (0, 1). Empty = fixed `weight` (no ramp). When a ramp is
    /// set, `weight` must be omitted (the ramp defines it).
    pub ramp: Vec<f64>,
    /// Clock time between ramp stage advances. Must be > 0 when `ramp`
    /// is non-empty.
    pub ramp_interval: Duration,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig {
            version: 2,
            weight: 0.1,
            ramp: Vec::new(),
            ramp_interval: Duration::from_secs(30),
        }
    }
}

impl ModelConfig {
    /// The incumbent version: the explicit `incumbent`, else the first
    /// listed version. `None` when the model is unversioned.
    pub fn incumbent_version(&self) -> Option<u32> {
        self.incumbent
            .or_else(|| self.versions.first().map(|v| v.version))
    }
}

/// Request-priority policy (`server.priorities`) — Triton's
/// dynamic-batcher priority levels (§2.1) end to end.
///
/// A request may carry an explicit priority on the wire; otherwise the
/// gateway resolves one here: per-token default first (a production
/// client identity maps to a class), then per-model default, then
/// `default`. The resolved class drives the batcher's admission lanes,
/// the overload-shedding order (bulk evicted first), and the gateway's
/// priority-aware rate limiting and pressure gating.
#[derive(Clone, Debug, PartialEq)]
pub struct PriorityConfig {
    /// Class assigned when neither the request, its token, nor its model
    /// names one.
    pub default: Priority,
    /// Per-model default classes (model name → class). Every named model
    /// must appear in `server.models`.
    pub models: BTreeMap<String, Priority>,
    /// Per-token default classes (auth token → class). Wins over the
    /// per-model default.
    pub tokens: BTreeMap<String, Priority>,
    /// Fraction of the gateway token-bucket burst reserved away from
    /// bulk traffic: a bulk request only takes a token while the bucket
    /// holds more than `bulk_reserve × rate_limit_burst` tokens, so
    /// higher classes keep headroom as the bucket drains.
    pub bulk_reserve: f64,
    /// Pressure-gate scaling for bulk: bulk is admitted only while the
    /// gate metric stays at or below `factor × threshold` (≤ 1, so bulk
    /// sheds first as pressure builds).
    pub bulk_pressure_factor: f64,
    /// Pressure-gate scaling for critical: critical is admitted up to
    /// `factor × threshold` (≥ 1, so critical sheds last).
    pub critical_pressure_factor: f64,
    /// Anti-starvation aging bound for the batcher's priority-first
    /// selection: a below-critical lane whose head has waited longer
    /// than this is promoted to the front of the next pop (once — it is
    /// served), so sustained critical saturation cannot starve bulk
    /// forever. Zero disables aging (the pure-priority PR-4 behavior).
    pub max_bulk_wait: Duration,
}

impl Default for PriorityConfig {
    fn default() -> Self {
        PriorityConfig {
            default: Priority::Standard,
            models: BTreeMap::new(),
            tokens: BTreeMap::new(),
            bulk_reserve: 0.25,
            bulk_pressure_factor: 0.5,
            critical_pressure_factor: 2.0,
            max_bulk_wait: Duration::ZERO,
        }
    }
}

impl PriorityConfig {
    /// Resolve one request's class: explicit wire priority, else the
    /// token's default, else the model's default, else `default`.
    pub fn resolve(&self, explicit: Option<Priority>, token: &str, model: &str) -> Priority {
        explicit
            .or_else(|| self.tokens.get(token).copied())
            .or_else(|| self.models.get(model).copied())
            .unwrap_or(self.default)
    }

    /// Pressure-gate threshold multiplier for one class.
    pub fn pressure_factor(&self, priority: Priority) -> f64 {
        match priority {
            Priority::Bulk => self.bulk_pressure_factor,
            Priority::Standard => 1.0,
            Priority::Critical => self.critical_pressure_factor,
        }
    }
}

/// Inference-server section (Triton analogue).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    /// Initial replica count (pods at boot).
    pub replicas: usize,
    /// Models each replica loads.
    pub models: Vec<ModelConfig>,
    /// Model repository root.
    pub repository: PathBuf,
    /// Simulated model-load time per replica start (pod ContainerCreating
    /// -> Running; the paper's GPU pods pull containers and load models).
    pub startup_delay: Duration,
    /// Real PJRT execution or calibrated simulated GPUs.
    pub execution: ExecutionMode,
    /// Per-instance queue capacity before load shedding.
    pub queue_capacity: usize,
    /// Utilization averaging window (clock seconds).
    pub util_window: f64,
    /// Batch admission policy: `affinity` (per-model queues, the default)
    /// or `fifo` (strict arrival order, the ablation baseline).
    pub batch_mode: BatchMode,
    /// Request-priority policy (classes, defaults, shed behavior).
    pub priorities: PriorityConfig,
}

/// Gateway section (Envoy analogue, §2.2).
#[derive(Clone, Debug, PartialEq)]
pub struct GatewayConfig {
    /// TCP listen address, e.g. "127.0.0.1:8001". Port 0 = ephemeral.
    pub listen: String,
    /// Load-balancing policy.
    pub lb_policy: LbPolicy,
    /// Token-bucket rate limit in requests/sec (0 disables).
    pub rate_limit_rps: f64,
    /// Token-bucket burst capacity.
    pub rate_limit_burst: usize,
    /// Shared-secret token auth (None disables). Tokens are HMAC-verified.
    pub auth_secret: Option<String>,
    /// Connection-handler threads.
    pub worker_threads: usize,
    /// Per-instance outstanding-request cap before the gateway sheds load
    /// (overload protection, §2.2 "preventing overloads").
    pub max_inflight_per_instance: usize,
    /// Open-connection cap at the listener (0 disables) — Envoy's
    /// connection limiting, §2.2 "based on the number of client
    /// connections".
    pub max_connections: usize,
}

/// RPC transport section (`rpc`): streaming multiplexed sessions.
///
/// Governs the wire layer on both sides of the gateway: how deep a
/// single client connection may pipeline, how many handler threads
/// demultiplex those pipelines, and how the gateway's session pool dials
/// backend instances when remote dispatch is enabled.
#[derive(Clone, Debug, PartialEq)]
pub struct RpcConfig {
    /// Warm sessions the gateway keeps per backend address. When every
    /// session is at the in-flight bound the pool reports exhaustion and
    /// the request is shed as retryable `Overloaded`
    /// (`rpc_pool_exhausted_total`).
    pub pool_size: usize,
    /// Pipelined requests allowed in flight per connection before the
    /// server blocks the connection reader (TCP backpressure); the
    /// session pool also refuses to check out sessions at this depth.
    /// 0 disables the bound.
    pub max_inflight_per_conn: usize,
    /// Per-request deadline on pooled sessions and io timeout on
    /// blocking clients that opt in: a hung backend surfaces as a
    /// retryable error instead of blocking the caller forever.
    pub io_timeout: Duration,
    /// Shared demultiplexing handler threads at the gateway listener.
    /// 0 keeps the sequential one-request-per-connection mode; set > 0
    /// so pipelined sessions actually execute concurrently.
    pub dispatch_threads: usize,
    /// Forward routed requests to instances over their sonic-rpc
    /// endpoints (through the session pool) instead of the in-process
    /// submit path. The networked hop the paper's Envoy → Triton leg
    /// takes; off by default because in-process dispatch is faster for
    /// single-host simulation.
    pub remote_dispatch: bool,
}

/// Per-model autoscaling subsection (`autoscaler.per_model`).
///
/// When enabled, the single global replica target is replaced by one
/// target per served model: the autoscaler runs one
/// [`ScalerCore`](crate::autoscaler::ScalerCore) per model, fed by the
/// placement controller's per-model demand signal (routed-request rate
/// plus live queue depth, per replica) instead of a cluster-wide metric.
/// Pods spawned for a hot model boot advertising only that model (its
/// "boot profile"). Requires the modelmesh (per-model routing supplies
/// the demand signal) and `autoscaler.enabled`.
///
/// The per-model loop inherits `poll_interval`, `scale_up_cooldown`,
/// `scale_down_stabilization`, `scale_down_ratio` and `step` from the
/// parent section; `autoscaler.max_replicas` stays the *total* pod
/// budget shared by all models.
#[derive(Clone, Debug, PartialEq)]
pub struct PerModelScalingConfig {
    /// Switch from one global replica target to per-model targets.
    pub enabled: bool,
    /// Per-replica demand (routed req/s + queued requests) above which a
    /// model gets another dedicated pod.
    pub threshold: f64,
    /// Per-model pod floor (a model never targets fewer pods).
    pub min_replicas: usize,
    /// Per-model pod cap (further capped by the shared
    /// `autoscaler.max_replicas` budget).
    pub max_replicas: usize,
}

impl Default for PerModelScalingConfig {
    fn default() -> Self {
        PerModelScalingConfig {
            enabled: false,
            threshold: 50.0,
            min_replicas: 1,
            max_replicas: 4,
        }
    }
}

/// Autoscaler section (KEDA analogue, §2.4).
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscalerConfig {
    /// Master switch; false = static deployment (the paper's baseline).
    pub enabled: bool,
    /// Metric that triggers scaling. The paper's default is the average
    /// request queue latency across Triton servers.
    pub metric: String,
    /// Scale up when the metric exceeds this (seconds for latency metrics).
    pub threshold: f64,
    /// Scale down when the metric falls below `threshold * scale_down_ratio`.
    pub scale_down_ratio: f64,
    /// Replica bounds.
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Metric poll interval.
    pub poll_interval: Duration,
    /// Minimum time between consecutive scale-ups.
    pub scale_up_cooldown: Duration,
    /// Minimum time the metric must stay low before scale-down (KEDA's
    /// stabilization window).
    pub scale_down_stabilization: Duration,
    /// Replicas added per scale-up step.
    pub step: usize,
    /// Per-model scaling (replaces the global target when enabled).
    pub per_model: PerModelScalingConfig,
}

/// Model placement policies (the modelmesh subsystem).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// The initial placement never changes (all-models-everywhere when
    /// the memory budget is unlimited; a balanced rotation otherwise).
    Static,
    /// A reconcile loop loads/unloads models per instance from demand
    /// (request rate + queue depth) under the memory budget.
    Dynamic,
}

impl PlacementPolicy {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "static" => PlacementPolicy::Static,
            "dynamic" => PlacementPolicy::Dynamic,
            other => bail!("unknown placement policy '{other}' (expected static or dynamic)"),
        })
    }

    /// Canonical config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Static => "static",
            PlacementPolicy::Dynamic => "dynamic",
        }
    }
}

/// Model placement section (`model_placement`) — dynamic model loading
/// and model-aware routing. With the default (`static` policy, unlimited
/// memory budget) the deployment behaves exactly like the base paper
/// setup: one global balancer, every instance serving every model. Any
/// other combination activates the modelmesh: per-model load balancers
/// plus per-instance serving sets.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPlacementConfig {
    /// `static` or `dynamic`.
    pub policy: PlacementPolicy,
    /// Per-instance simulated GPU-memory budget in MB (f32 weights: a
    /// model costs 4 bytes per parameter). 0 = unlimited.
    pub memory_budget_mb: f64,
    /// Per-replica demand (requests/sec + queued requests) above which a
    /// model gets another replica.
    pub load_threshold: f64,
    /// Per-replica demand below which a surplus replica may be dropped.
    /// Must stay below `load_threshold` (hysteresis band).
    pub unload_threshold: f64,
    /// Minimum time between placement changes for the same
    /// (instance, model) pair.
    pub cooldown: Duration,
    /// Trailing window for the routed-request-rate demand signal.
    pub demand_window: Duration,
    /// A model never shrinks below this many replicas.
    pub min_replicas_per_model: usize,
    /// Simulated warm-load time: a placement load spends this long in the
    /// `Loading` state (excluded from router pools and from placement's
    /// warm serving sets) before the replica serves. 0 = instantaneous
    /// loads (the pre-cost-model behavior). Per-model override:
    /// `server.models[].load_delay`.
    pub load_delay: Duration,
}

impl ModelPlacementConfig {
    /// Memory budget in bytes (0 = unlimited).
    pub fn budget_bytes(&self) -> u64 {
        (self.memory_budget_mb * 1e6) as u64
    }

    /// Amortization horizon for the warm-load cost model: the minimum
    /// clock time a planned load survives before it can be reverted
    /// (cooldown) or re-judged against fresh demand (demand window). A
    /// new replica spends `load_delay` of this horizon cold, so the
    /// placement planner discounts its expected benefit accordingly.
    pub fn load_cost_horizon(&self) -> Duration {
        self.cooldown.max(self.demand_window)
    }

    /// Is the modelmesh (per-model routing + placement) active?
    pub fn mesh_enabled(&self) -> bool {
        self.policy == PlacementPolicy::Dynamic || self.memory_budget_mb > 0.0
    }
}

/// Multi-backend engine section (`engines`) — the pluggable runtime
/// layer (Triton's TensorRT / ONNX Runtime backend menu, the paper's
/// "different backends and coprocessor types" portability claim).
///
/// Two backends exist: `pjrt` (the compiled-artifact runtime; GPU-class
/// pods only) and `onnx-sim` (a deterministic simulated CPU-capable
/// second runtime with its own cost model). Each served model resolves
/// a backend *preference list* — `server.models[].backends` when set,
/// else `default_backend` followed by every other backend — and an
/// instance serves the model on the first preferred backend its
/// accelerator class supports (anything later is a *fallback*, counted
/// in `backend_fallback_total`). `cpu_replicas` boots CPU-class pods
/// next to the GPU fleet, turning the deployment heterogeneous.
#[derive(Clone, Debug, PartialEq)]
pub struct EnginesConfig {
    /// Backend preferred by models that list none (see [`BACKEND_NAMES`]).
    pub default_backend: String,
    /// CPU-class pods booted alongside the GPU fleet. They advertise
    /// only CPU-capable backends, so they serve exactly the models
    /// whose preference list includes one. Requires the modelmesh
    /// (routing must follow advertised labels on a split fleet).
    pub cpu_replicas: usize,
    /// Ceiling for per-model CPU autoscaling: when above `cpu_replicas`
    /// (and the per-model scaler is enabled), a dedicated CPU trigger —
    /// fed only by the CPU-attributed share of each CPU-servable model's
    /// demand, so GPU load cannot ratchet CPU pods — drives
    /// `Cluster::set_cpu_desired` between `cpu_replicas` (floor) and
    /// this cap. 0 (default) = the CPU group stays statically sized.
    pub cpu_max_replicas: usize,
    /// onnx-sim latency multiplier over the model's calibrated GPU
    /// service model (CPU inference is slower). Must be > 0.
    pub onnx_slowdown: f64,
    /// onnx-sim warm-load delay multiplier (session init vs engine
    /// build). Must be > 0.
    pub onnx_load_multiplier: f64,
    /// onnx-sim memory-footprint multiplier. Must be in (0, 1]: the
    /// placement planner budgets with the unscaled footprint, so a
    /// multiplier above 1 could overcommit an instance's memory.
    pub onnx_memory_multiplier: f64,
}

impl Default for EnginesConfig {
    fn default() -> Self {
        EnginesConfig {
            default_backend: "pjrt".into(),
            cpu_replicas: 0,
            cpu_max_replicas: 0,
            onnx_slowdown: 4.0,
            onnx_load_multiplier: 0.5,
            onnx_memory_multiplier: 1.0,
        }
    }
}

impl EnginesConfig {
    /// Largest CPU group any configuration can reach (the scaler's
    /// ceiling when CPU autoscaling is on, the static size otherwise).
    pub fn effective_cpu_max(&self) -> usize {
        self.cpu_max_replicas.max(self.cpu_replicas)
    }

    /// Is the per-model CPU scaler configured to actually move the group?
    pub fn cpu_scaling_enabled(&self) -> bool {
        self.cpu_max_replicas > self.cpu_replicas
    }
}

/// Cluster substrate section (Kubernetes analogue).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Node count in the simulated cluster.
    pub nodes: usize,
    /// GPU slots per node (pods needing a GPU bind to a slot).
    pub gpus_per_node: usize,
    /// Simulated pod-start latency (scheduling + container pull), on top
    /// of the server's model-load `startup_delay`.
    pub pod_start_delay: Duration,
    /// Graceful termination period on scale-down.
    pub termination_grace: Duration,
    /// Probability a pod start fails and is retried (failure injection).
    pub pod_failure_rate: f64,
}

/// One federation site (`federation.sites[]`): an independent cluster
/// with its own pod budget, accelerator mix and WAN distance to the
/// other sites (the paper's Purdue / NRP / UChicago facilities).
#[derive(Clone, Debug, PartialEq)]
pub struct SiteConfig {
    /// Site name (labels every per-site metric series and pod name).
    pub name: String,
    /// Ceiling on GPU pods the per-site scaler may run. The global
    /// rebalancer shifts budget *between* sites, conserving the sum of
    /// the configured budgets.
    pub pod_budget: usize,
    /// Initial GPU pods booted at this site.
    pub replicas: usize,
    /// Node count of this site's cluster.
    pub nodes: usize,
    /// GPU slots per node at this site.
    pub gpus_per_node: usize,
    /// CPU-class pods booted at this site (accelerator mix).
    pub cpu_replicas: usize,
    /// WAN round-trip latency from this site to each named peer site
    /// (float seconds). Missing peers (and the site itself) cost zero.
    /// The federation gateway is homed at `federation.gateway_site`, so
    /// only that site's map prices remote hops.
    pub wan: BTreeMap<String, Duration>,
}

impl Default for SiteConfig {
    fn default() -> Self {
        SiteConfig {
            name: String::new(),
            pod_budget: 4,
            replicas: 1,
            nodes: 2,
            gpus_per_node: 2,
            cpu_replicas: 0,
            wan: BTreeMap::new(),
        }
    }
}

/// Multi-site federation section (`federation`). Empty `sites` (the
/// default) keeps the deployment single-cluster and byte-identical to
/// the pre-federation behavior. With two or more sites the control
/// plane goes hierarchical: per-site clusters, placement loops and
/// per-model scalers, a federation-tier gateway routing each model's
/// traffic to the cheapest site with warm capacity, and a global
/// rebalancer shifting pod budget between sites from site-labeled
/// demand.
#[derive(Clone, Debug, PartialEq)]
pub struct FederationConfig {
    /// The federated sites. Empty = federation off (single cluster).
    pub sites: Vec<SiteConfig>,
    /// Site the federation gateway is homed at (its `wan` map prices
    /// remote hops). Empty = the first listed site.
    pub gateway_site: String,
    /// Cadence of the global budget rebalancer (and of its site-outage
    /// detection).
    pub rebalance_interval: Duration,
    /// Mean queued requests per warm replica above which a site counts
    /// as saturated: the federation router then spills the model's
    /// traffic over to the next-cheapest site with warm capacity.
    pub spillover_queue_depth: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            sites: Vec::new(),
            gateway_site: String::new(),
            rebalance_interval: Duration::from_secs(5),
            spillover_queue_depth: 8.0,
        }
    }
}

impl FederationConfig {
    /// Is multi-site federation active?
    pub fn enabled(&self) -> bool {
        !self.sites.is_empty()
    }

    /// The effective gateway home site (explicit or first listed).
    pub fn gateway_site(&self) -> &str {
        if self.gateway_site.is_empty() {
            self.sites.first().map(|s| s.name.as_str()).unwrap_or("")
        } else {
            &self.gateway_site
        }
    }

    /// Sum of the configured per-site pod budgets (conserved by the
    /// rebalancer).
    pub fn total_budget(&self) -> usize {
        self.sites.iter().map(|s| s.pod_budget).sum()
    }
}

/// Monitoring section (Prometheus analogue, §2.3).
#[derive(Clone, Debug, PartialEq)]
pub struct MonitoringConfig {
    /// Metrics HTTP endpoint ("127.0.0.1:0" = ephemeral port, "" = off).
    pub listen: String,
    /// Scrape/aggregation interval.
    pub scrape_interval: Duration,
    /// Retention window for time series.
    pub retention: Duration,
    /// Enable per-request span tracing (OpenTelemetry analogue).
    pub tracing: bool,
}

/// One per-model SLO target (`observability.slos[]`).
#[derive(Clone, Debug, PartialEq)]
pub struct SloConfig {
    /// Model the objective applies to (must be in `server.models`).
    pub model: String,
    /// Latency objective: 99% of OK requests complete within this bound
    /// (the implied error budget is the remaining 1%).
    pub latency_p99: Duration,
    /// Allowed fraction of non-OK responses (error-rate budget).
    pub error_budget: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            model: String::new(),
            latency_p99: Duration::from_millis(500),
            error_budget: 0.01,
        }
    }
}

/// Observability section: tracing depth/sampling and the SLO burn-rate
/// alerting engine (§2.3's Tempo + Grafana-alerting analogue).
#[derive(Clone, Debug, PartialEq)]
pub struct ObservabilityConfig {
    /// Head-sampling rate for traces in [0, 1] (decided once per trace
    /// id, propagated on the wire so every hop agrees).
    pub trace_sample_rate: f64,
    /// Span buffer capacity (ring semantics; evictions are counted on
    /// `trace_spans_dropped_total` and mark affected traces partial).
    pub trace_capacity: usize,
    /// Control-plane flight recorder capacity: decision events retained
    /// (ring semantics). 0 disables the recorder entirely.
    pub flight_recorder_capacity: usize,
    /// How far back `explain` looks (clock seconds) when no explicit
    /// `since` bound is given.
    pub explain_horizon: Duration,
    /// Fast burn-rate window (the "5m" of the multi-window rule).
    pub slo_fast_window: Duration,
    /// Slow burn-rate window (the "1h" of the multi-window rule).
    pub slo_slow_window: Duration,
    /// Evaluation cadence of the SLO engine.
    pub slo_eval_interval: Duration,
    /// Burn-rate multiple (of budget) at which alerts fire.
    pub slo_burn_threshold: f64,
    /// Per-model SLO targets; empty disables the engine.
    pub slos: Vec<SloConfig>,
    /// Canary auto-rollback: the canary's windowed p99 may exceed the
    /// incumbent's by at most this factor before rollback fires (both
    /// burn windows must agree). Must be >= 1.
    pub rollback_latency_factor: f64,
    /// Canary auto-rollback: absolute error-rate margin the canary may
    /// exceed the incumbent by before rollback fires.
    pub rollback_error_margin: f64,
    /// Minimum windowed request count (per arm) before the rollback
    /// comparison is trusted — guards against deciding on noise.
    pub rollback_min_requests: u64,
}

/// Whole-deployment configuration (the Helm values analogue).
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentConfig {
    /// Deployment name (labels metrics and logs).
    pub name: String,
    pub server: ServerConfig,
    pub gateway: GatewayConfig,
    /// RPC transport tuning (session pooling, pipelining, io timeouts).
    pub rpc: RpcConfig,
    pub autoscaler: AutoscalerConfig,
    pub cluster: ClusterConfig,
    /// Multi-site federation (empty `sites` = single-cluster mode).
    pub federation: FederationConfig,
    pub monitoring: MonitoringConfig,
    /// Model placement / model-aware routing (the modelmesh).
    pub model_placement: ModelPlacementConfig,
    /// Multi-backend engine layer (backend preferences, CPU fleet).
    pub engines: EnginesConfig,
    /// Tracing depth/sampling and SLO burn-rate alerting.
    pub observability: ObservabilityConfig,
    /// Wall-clock dilation factor for experiments (1.0 = real time). See
    /// `util::clock`.
    pub time_scale: f64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            name: "particlenet".into(),
            max_queue_delay: Duration::from_millis(2),
            preferred_batch: 8,
            service_model: ServiceModelConfig::default(),
            load_delay: None,
            backends: Vec::new(),
            versions: Vec::new(),
            incumbent: None,
            canary: None,
            pinned_version: None,
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            replicas: 1,
            models: vec![ModelConfig::default()],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_secs(2),
            execution: ExecutionMode::Real,
            queue_capacity: 256,
            util_window: 10.0,
            batch_mode: BatchMode::Affinity,
            priorities: PriorityConfig::default(),
        }
    }
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::RoundRobin,
            rate_limit_rps: 0.0,
            rate_limit_burst: 64,
            auth_secret: None,
            worker_threads: 8,
            max_inflight_per_instance: 32,
            max_connections: 0,
        }
    }
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            enabled: false,
            metric: "queue_latency_avg".into(),
            threshold: 0.050,
            scale_down_ratio: 0.3,
            min_replicas: 1,
            max_replicas: 8,
            poll_interval: Duration::from_secs(2),
            scale_up_cooldown: Duration::from_secs(4),
            scale_down_stabilization: Duration::from_secs(20),
            step: 1,
            per_model: PerModelScalingConfig::default(),
        }
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            gpus_per_node: 4,
            pod_start_delay: Duration::from_secs(3),
            termination_grace: Duration::from_secs(1),
            pod_failure_rate: 0.0,
        }
    }
}

impl Default for ModelPlacementConfig {
    fn default() -> Self {
        ModelPlacementConfig {
            policy: PlacementPolicy::Static,
            memory_budget_mb: 0.0,
            load_threshold: 50.0,
            unload_threshold: 10.0,
            cooldown: Duration::from_secs(10),
            demand_window: Duration::from_secs(15),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        }
    }
}

impl Default for MonitoringConfig {
    fn default() -> Self {
        MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(3600),
            tracing: false,
        }
    }
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        ObservabilityConfig {
            trace_sample_rate: 1.0,
            trace_capacity: 65536,
            flight_recorder_capacity: 4096,
            explain_horizon: Duration::from_secs(600),
            slo_fast_window: Duration::from_secs(300),
            slo_slow_window: Duration::from_secs(3600),
            slo_eval_interval: Duration::from_secs(5),
            slo_burn_threshold: 10.0,
            slos: Vec::new(),
            rollback_latency_factor: 2.0,
            rollback_error_margin: 0.05,
            rollback_min_requests: 20,
        }
    }
}

impl Default for RpcConfig {
    fn default() -> Self {
        RpcConfig {
            pool_size: 4,
            max_inflight_per_conn: 64,
            io_timeout: Duration::from_secs(10),
            dispatch_threads: 0,
            remote_dispatch: false,
        }
    }
}

impl Default for DeploymentConfig {
    fn default() -> Self {
        DeploymentConfig {
            name: "supersonic".into(),
            server: ServerConfig::default(),
            gateway: GatewayConfig::default(),
            rpc: RpcConfig::default(),
            autoscaler: AutoscalerConfig::default(),
            cluster: ClusterConfig::default(),
            federation: FederationConfig::default(),
            monitoring: MonitoringConfig::default(),
            model_placement: ModelPlacementConfig::default(),
            engines: EnginesConfig::default(),
            observability: ObservabilityConfig::default(),
            time_scale: 1.0,
        }
    }
}

/// Allowed key sets per config section — the single source of truth
/// shared by the parser's unknown-key rejection and the
/// `docs/CONFIG.md` sync test (`config_doc_covers_every_schema_field`).
/// Adding a field here without documenting it fails the test suite.
pub mod keys {
    /// Top-level sections.
    pub const ROOT: &[&str] = &[
        "name", "server", "gateway", "rpc", "autoscaler", "cluster", "federation",
        "monitoring", "model_placement", "engines", "observability", "time_scale",
    ];
    /// `server` section.
    pub const SERVER: &[&str] = &[
        "replicas", "models", "repository", "startup_delay", "execution",
        "queue_capacity", "util_window", "batch_mode", "priorities",
    ];
    /// `server.priorities` subsection.
    pub const PRIORITIES: &[&str] = &[
        "default", "models", "tokens", "bulk_reserve", "bulk_pressure_factor",
        "critical_pressure_factor", "max_bulk_wait",
    ];
    /// `server.models[]` entries.
    pub const SERVER_MODEL: &[&str] = &[
        "name", "max_queue_delay", "preferred_batch", "service_model", "load_delay",
        "backends", "versions", "incumbent", "canary", "pinned_version",
    ];
    /// `server.models[].service_model`.
    pub const SERVICE_MODEL: &[&str] = &["base", "per_row"];
    /// `server.models[].versions[]` map entries (a list item may also be
    /// a bare version number).
    pub const VERSION: &[&str] = &["version", "slowdown"];
    /// `server.models[].canary`.
    pub const CANARY: &[&str] = &["version", "weight", "ramp", "ramp_interval"];
    /// `gateway` section.
    pub const GATEWAY: &[&str] = &[
        "listen", "lb_policy", "rate_limit_rps", "rate_limit_burst", "auth_secret",
        "worker_threads", "max_inflight_per_instance", "max_connections",
    ];
    /// `rpc` section (streaming multiplexed transport).
    pub const RPC: &[&str] = &[
        "pool_size", "max_inflight_per_conn", "io_timeout", "dispatch_threads",
        "remote_dispatch",
    ];
    /// `autoscaler` section.
    pub const AUTOSCALER: &[&str] = &[
        "enabled", "metric", "threshold", "scale_down_ratio", "min_replicas",
        "max_replicas", "poll_interval", "scale_up_cooldown",
        "scale_down_stabilization", "step", "per_model",
    ];
    /// `autoscaler.per_model` subsection.
    pub const AUTOSCALER_PER_MODEL: &[&str] =
        &["enabled", "threshold", "min_replicas", "max_replicas"];
    /// `cluster` section.
    pub const CLUSTER: &[&str] = &[
        "nodes", "gpus_per_node", "pod_start_delay", "termination_grace",
        "pod_failure_rate",
    ];
    /// `federation` section (multi-site mode).
    pub const FEDERATION: &[&str] = &[
        "sites", "gateway_site", "rebalance_interval", "spillover_queue_depth",
    ];
    /// `federation.sites[]` entries.
    pub const FEDERATION_SITE: &[&str] = &[
        "name", "pod_budget", "replicas", "nodes", "gpus_per_node", "cpu_replicas",
        "wan",
    ];
    /// `monitoring` section.
    pub const MONITORING: &[&str] = &["listen", "scrape_interval", "retention", "tracing"];
    /// `model_placement` section.
    pub const MODEL_PLACEMENT: &[&str] = &[
        "policy", "memory_budget_mb", "load_threshold", "unload_threshold",
        "cooldown", "demand_window", "min_replicas_per_model", "load_delay",
    ];
    /// `engines` section (the multi-backend layer).
    pub const ENGINES: &[&str] = &[
        "default_backend", "cpu_replicas", "cpu_max_replicas", "onnx_slowdown",
        "onnx_load_multiplier", "onnx_memory_multiplier",
    ];
    /// `observability` section (tracing + SLO alerting).
    pub const OBSERVABILITY: &[&str] = &[
        "trace_sample_rate", "trace_capacity", "flight_recorder_capacity",
        "explain_horizon", "slo_fast_window", "slo_slow_window",
        "slo_eval_interval", "slo_burn_threshold", "slos",
        "rollback_latency_factor", "rollback_error_margin", "rollback_min_requests",
    ];
    /// `observability.slos[]` entries.
    pub const OBSERVABILITY_SLO: &[&str] = &["model", "latency_p99", "error_budget"];
    /// Every (section, allowed keys) pair, for exhaustive iteration.
    pub const SECTIONS: &[(&str, &[&str])] = &[
        ("<root>", ROOT),
        ("server", SERVER),
        ("server.priorities", PRIORITIES),
        ("server.models[]", SERVER_MODEL),
        ("server.models[].service_model", SERVICE_MODEL),
        ("server.models[].versions[]", VERSION),
        ("server.models[].canary", CANARY),
        ("gateway", GATEWAY),
        ("rpc", RPC),
        ("autoscaler", AUTOSCALER),
        ("autoscaler.per_model", AUTOSCALER_PER_MODEL),
        ("cluster", CLUSTER),
        ("federation", FEDERATION),
        ("federation.sites[]", FEDERATION_SITE),
        ("monitoring", MONITORING),
        ("model_placement", MODEL_PLACEMENT),
        ("engines", ENGINES),
        ("observability", OBSERVABILITY),
        ("observability.slos[]", OBSERVABILITY_SLO),
    ];
}

// ---------------------------------------------------------------------------
// parsing helpers
// ---------------------------------------------------------------------------

fn check_keys(v: &Value, allowed: &[&str], section: &str) -> Result<()> {
    for key in v.keys() {
        if !allowed.contains(&key) {
            bail!(
                "unknown key '{key}' in section '{section}' \
                 (allowed: {})",
                allowed.join(", ")
            );
        }
    }
    Ok(())
}

fn get_usize(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => {
            let i = x
                .as_i64()
                .with_context(|| format!("'{key}' must be an integer"))?;
            if i < 0 {
                bail!("'{key}' must be non-negative, got {i}");
            }
            Ok(i as usize)
        }
    }
}

fn get_f64(v: &Value, key: &str, default: f64) -> Result<f64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x
            .as_f64()
            .with_context(|| format!("'{key}' must be a number")),
    }
}

fn get_bool(v: &Value, key: &str, default: bool) -> Result<bool> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x
            .as_bool()
            .with_context(|| format!("'{key}' must be a bool")),
    }
}

fn get_str(v: &Value, key: &str, default: &str) -> Result<String> {
    match v.get(key) {
        None => Ok(default.to_string()),
        Some(x) => Ok(x
            .as_str()
            .with_context(|| format!("'{key}' must be a string"))?
            .to_string()),
    }
}

/// A version number: a non-negative integer that fits in u32.
fn version_number(x: &Value, what: &str) -> Result<u32> {
    let i = x
        .as_i64()
        .with_context(|| format!("'{what}' must be an integer version"))?;
    if i < 0 || i > u32::MAX as i64 {
        bail!("'{what}' version out of range: {i}");
    }
    Ok(i as u32)
}

/// Durations are written as float seconds (e.g. `poll_interval: 0.5`).
fn get_duration(v: &Value, key: &str, default: Duration) -> Result<Duration> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => {
            let secs = x
                .as_f64()
                .with_context(|| format!("'{key}' must be seconds (number)"))?;
            if secs < 0.0 {
                bail!("'{key}' must be non-negative");
            }
            Ok(Duration::from_secs_f64(secs))
        }
    }
}

impl DeploymentConfig {
    /// Effective warm-load delay for one served model: the per-model
    /// `load_delay` override when set, `model_placement.load_delay`
    /// otherwise.
    pub fn effective_load_delay(&self, model: &ModelConfig) -> Duration {
        model.load_delay.unwrap_or(self.model_placement.load_delay)
    }

    /// Parse from YAML text; missing sections/keys use defaults, unknown
    /// keys are errors.
    pub fn from_yaml(text: &str) -> Result<Self> {
        let root = yaml::parse(text).context("parsing deployment config")?;
        Self::from_value(&root)
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_yaml(&text).with_context(|| format!("in config {}", path.display()))
    }

    /// Parse from an already-parsed YAML value.
    pub fn from_value(root: &Value) -> Result<Self> {
        check_keys(root, keys::ROOT, "<root>")?;
        let d = DeploymentConfig::default();
        let empty = Value::Map(Vec::new());

        let name = get_str(root, "name", &d.name)?;
        let time_scale = get_f64(root, "time_scale", d.time_scale)?;

        let sv = root.get("server").unwrap_or(&empty);
        check_keys(sv, keys::SERVER, "server")?;
        let models = match sv.get("models") {
            None => d.server.models.clone(),
            Some(list) => {
                let items = list
                    .as_seq()
                    .context("'server.models' must be a sequence")?;
                let mut models = Vec::new();
                for item in items {
                    check_keys(item, keys::SERVER_MODEL, "server.models[]")?;
                    let dm = ModelConfig::default();
                    let service_model = match item.get("service_model") {
                        None => dm.service_model,
                        Some(sm) => {
                            check_keys(
                                sm,
                                keys::SERVICE_MODEL,
                                "server.models[].service_model",
                            )?;
                            ServiceModelConfig {
                                base: get_duration(sm, "base", dm.service_model.base)?,
                                per_row: get_duration(sm, "per_row", dm.service_model.per_row)?,
                            }
                        }
                    };
                    let load_delay = match item.get("load_delay") {
                        None => None,
                        Some(_) => Some(get_duration(item, "load_delay", Duration::ZERO)?),
                    };
                    let backends = match item.get("backends") {
                        None => Vec::new(),
                        Some(list) => list
                            .as_seq()
                            .context("'server.models[].backends' must be a sequence")?
                            .iter()
                            .map(|b| {
                                b.as_str()
                                    .context("'backends' entries must be backend names")
                                    .map(String::from)
                            })
                            .collect::<Result<_>>()?,
                    };
                    let versions = match item.get("versions") {
                        None => Vec::new(),
                        Some(list) => {
                            let entries = list
                                .as_seq()
                                .context("'server.models[].versions' must be a sequence")?;
                            let mut out = Vec::new();
                            for entry in entries {
                                // A bare integer is shorthand for
                                // `{version: N}` with default knobs.
                                if entry.as_i64().is_some() {
                                    out.push(VersionSpec {
                                        version: version_number(
                                            entry,
                                            "server.models[].versions[]",
                                        )?,
                                        slowdown: 1.0,
                                    });
                                    continue;
                                }
                                check_keys(entry, keys::VERSION, "server.models[].versions[]")?;
                                let v = entry.get("version").context(
                                    "'server.models[].versions[]' map entries need 'version'",
                                )?;
                                out.push(VersionSpec {
                                    version: version_number(
                                        v,
                                        "server.models[].versions[].version",
                                    )?,
                                    slowdown: get_f64(entry, "slowdown", 1.0)?,
                                });
                            }
                            out
                        }
                    };
                    let incumbent = match item.get("incumbent") {
                        None => None,
                        Some(x) => Some(version_number(x, "server.models[].incumbent")?),
                    };
                    let canary = match item.get("canary") {
                        None => None,
                        Some(c) => {
                            check_keys(c, keys::CANARY, "server.models[].canary")?;
                            let v = c
                                .get("version")
                                .context("'server.models[].canary' needs 'version'")?;
                            let ramp = match c.get("ramp") {
                                None => Vec::new(),
                                Some(list) => list
                                    .as_seq()
                                    .context("'canary.ramp' must be a sequence of weights")?
                                    .iter()
                                    .map(|w| {
                                        w.as_f64()
                                            .context("'canary.ramp' entries must be numbers")
                                    })
                                    .collect::<Result<_>>()?,
                            };
                            let weight = match (c.get("weight"), ramp.first()) {
                                (Some(w), None) => {
                                    w.as_f64().context("'canary.weight' must be a number")?
                                }
                                // The ramp defines the weight schedule;
                                // a separate fixed weight would conflict.
                                (Some(_), Some(_)) => bail!(
                                    "'server.models[].canary' sets both 'weight' and \
                                     'ramp'; the ramp's first stage is the starting \
                                     weight — drop 'weight'"
                                ),
                                (None, Some(first)) => *first,
                                (None, None) => bail!(
                                    "'server.models[].canary' needs 'weight' (or a 'ramp')"
                                ),
                            };
                            let dc = CanaryConfig::default();
                            Some(CanaryConfig {
                                version: version_number(v, "server.models[].canary.version")?,
                                weight,
                                ramp,
                                ramp_interval: get_duration(
                                    c,
                                    "ramp_interval",
                                    dc.ramp_interval,
                                )?,
                            })
                        }
                    };
                    let pinned_version = match item.get("pinned_version") {
                        None => None,
                        Some(x) => Some(version_number(x, "server.models[].pinned_version")?),
                    };
                    models.push(ModelConfig {
                        name: get_str(item, "name", "")?,
                        max_queue_delay: get_duration(item, "max_queue_delay", dm.max_queue_delay)?,
                        preferred_batch: get_usize(item, "preferred_batch", dm.preferred_batch)?,
                        service_model,
                        load_delay,
                        backends,
                        versions,
                        incumbent,
                        canary,
                        pinned_version,
                    });
                }
                models
            }
        };
        let pr = sv.get("priorities").unwrap_or(&empty);
        check_keys(pr, keys::PRIORITIES, "server.priorities")?;
        fn parse_priority_map(
            v: Option<&Value>,
            section: &str,
        ) -> Result<BTreeMap<String, Priority>> {
            let mut out = BTreeMap::new();
            if let Some(v) = v {
                let entries = v
                    .as_map()
                    .with_context(|| format!("'{section}' must be a map of name: priority"))?;
                for (name, class) in entries {
                    let class = class
                        .as_str()
                        .with_context(|| format!("'{section}.{name}' must be a priority name"))?;
                    out.insert(name.clone(), Priority::parse(class)?);
                }
            }
            Ok(out)
        }
        let priorities = PriorityConfig {
            default: match pr.get("default") {
                None => d.server.priorities.default,
                Some(x) => Priority::parse(
                    x.as_str().context("'priorities.default' must be a string")?,
                )?,
            },
            models: parse_priority_map(pr.get("models"), "server.priorities.models")?,
            tokens: parse_priority_map(pr.get("tokens"), "server.priorities.tokens")?,
            bulk_reserve: get_f64(pr, "bulk_reserve", d.server.priorities.bulk_reserve)?,
            bulk_pressure_factor: get_f64(
                pr,
                "bulk_pressure_factor",
                d.server.priorities.bulk_pressure_factor,
            )?,
            critical_pressure_factor: get_f64(
                pr,
                "critical_pressure_factor",
                d.server.priorities.critical_pressure_factor,
            )?,
            max_bulk_wait: get_duration(pr, "max_bulk_wait", d.server.priorities.max_bulk_wait)?,
        };
        let server = ServerConfig {
            replicas: get_usize(sv, "replicas", d.server.replicas)?,
            models,
            repository: PathBuf::from(get_str(sv, "repository", "artifacts")?),
            startup_delay: get_duration(sv, "startup_delay", d.server.startup_delay)?,
            execution: match sv.get("execution") {
                None => d.server.execution,
                Some(x) => ExecutionMode::parse(
                    x.as_str().context("'execution' must be a string")?,
                )?,
            },
            queue_capacity: get_usize(sv, "queue_capacity", d.server.queue_capacity)?,
            util_window: get_f64(sv, "util_window", d.server.util_window)?,
            batch_mode: match sv.get("batch_mode") {
                None => d.server.batch_mode,
                Some(x) => {
                    BatchMode::parse(x.as_str().context("'batch_mode' must be a string")?)?
                }
            },
            priorities,
        };

        let gw = root.get("gateway").unwrap_or(&empty);
        check_keys(gw, keys::GATEWAY, "gateway")?;
        let gateway = GatewayConfig {
            listen: get_str(gw, "listen", &d.gateway.listen)?,
            lb_policy: match gw.get("lb_policy") {
                None => d.gateway.lb_policy,
                Some(x) => LbPolicy::parse(x.as_str().context("'lb_policy' must be a string")?)?,
            },
            rate_limit_rps: get_f64(gw, "rate_limit_rps", d.gateway.rate_limit_rps)?,
            rate_limit_burst: get_usize(gw, "rate_limit_burst", d.gateway.rate_limit_burst)?,
            auth_secret: match gw.get("auth_secret") {
                None => None,
                Some(x) if x.is_null() => None,
                Some(x) => Some(x.as_str().context("'auth_secret' must be a string")?.to_string()),
            },
            worker_threads: get_usize(gw, "worker_threads", d.gateway.worker_threads)?,
            max_inflight_per_instance: get_usize(
                gw,
                "max_inflight_per_instance",
                d.gateway.max_inflight_per_instance,
            )?,
            max_connections: get_usize(gw, "max_connections", d.gateway.max_connections)?,
        };

        let rp = root.get("rpc").unwrap_or(&empty);
        check_keys(rp, keys::RPC, "rpc")?;
        let rpc = RpcConfig {
            pool_size: get_usize(rp, "pool_size", d.rpc.pool_size)?,
            max_inflight_per_conn: get_usize(
                rp,
                "max_inflight_per_conn",
                d.rpc.max_inflight_per_conn,
            )?,
            io_timeout: get_duration(rp, "io_timeout", d.rpc.io_timeout)?,
            dispatch_threads: get_usize(rp, "dispatch_threads", d.rpc.dispatch_threads)?,
            remote_dispatch: get_bool(rp, "remote_dispatch", d.rpc.remote_dispatch)?,
        };

        let asc = root.get("autoscaler").unwrap_or(&empty);
        check_keys(asc, keys::AUTOSCALER, "autoscaler")?;
        let pm = asc.get("per_model").unwrap_or(&empty);
        check_keys(pm, keys::AUTOSCALER_PER_MODEL, "autoscaler.per_model")?;
        let per_model = PerModelScalingConfig {
            enabled: get_bool(pm, "enabled", d.autoscaler.per_model.enabled)?,
            threshold: get_f64(pm, "threshold", d.autoscaler.per_model.threshold)?,
            min_replicas: get_usize(pm, "min_replicas", d.autoscaler.per_model.min_replicas)?,
            max_replicas: get_usize(pm, "max_replicas", d.autoscaler.per_model.max_replicas)?,
        };
        let autoscaler = AutoscalerConfig {
            enabled: get_bool(asc, "enabled", d.autoscaler.enabled)?,
            metric: get_str(asc, "metric", &d.autoscaler.metric)?,
            threshold: get_f64(asc, "threshold", d.autoscaler.threshold)?,
            scale_down_ratio: get_f64(asc, "scale_down_ratio", d.autoscaler.scale_down_ratio)?,
            min_replicas: get_usize(asc, "min_replicas", d.autoscaler.min_replicas)?,
            max_replicas: get_usize(asc, "max_replicas", d.autoscaler.max_replicas)?,
            poll_interval: get_duration(asc, "poll_interval", d.autoscaler.poll_interval)?,
            scale_up_cooldown: get_duration(asc, "scale_up_cooldown", d.autoscaler.scale_up_cooldown)?,
            scale_down_stabilization: get_duration(
                asc,
                "scale_down_stabilization",
                d.autoscaler.scale_down_stabilization,
            )?,
            step: get_usize(asc, "step", d.autoscaler.step)?,
            per_model,
        };

        let cl = root.get("cluster").unwrap_or(&empty);
        check_keys(cl, keys::CLUSTER, "cluster")?;
        let cluster = ClusterConfig {
            nodes: get_usize(cl, "nodes", d.cluster.nodes)?,
            gpus_per_node: get_usize(cl, "gpus_per_node", d.cluster.gpus_per_node)?,
            pod_start_delay: get_duration(cl, "pod_start_delay", d.cluster.pod_start_delay)?,
            termination_grace: get_duration(cl, "termination_grace", d.cluster.termination_grace)?,
            pod_failure_rate: get_f64(cl, "pod_failure_rate", d.cluster.pod_failure_rate)?,
        };

        let fe = root.get("federation").unwrap_or(&empty);
        check_keys(fe, keys::FEDERATION, "federation")?;
        let sites = match fe.get("sites") {
            None => Vec::new(),
            Some(list) => {
                let items = list
                    .as_seq()
                    .context("'federation.sites' must be a sequence")?;
                let mut sites = Vec::new();
                for item in items {
                    check_keys(item, keys::FEDERATION_SITE, "federation.sites[]")?;
                    let ds = SiteConfig::default();
                    let wan = match item.get("wan") {
                        None => BTreeMap::new(),
                        Some(map) => {
                            let entries = map.as_map().context(
                                "'federation.sites[].wan' must be a map of \
                                 site: seconds",
                            )?;
                            let mut wan = BTreeMap::new();
                            for (peer, secs) in entries {
                                let secs = secs.as_f64().with_context(|| {
                                    format!("'wan.{peer}' must be seconds (number)")
                                })?;
                                if secs < 0.0 {
                                    bail!("'wan.{peer}' must be non-negative");
                                }
                                wan.insert(peer.clone(), Duration::from_secs_f64(secs));
                            }
                            wan
                        }
                    };
                    sites.push(SiteConfig {
                        name: get_str(item, "name", "")?,
                        pod_budget: get_usize(item, "pod_budget", ds.pod_budget)?,
                        replicas: get_usize(item, "replicas", ds.replicas)?,
                        nodes: get_usize(item, "nodes", ds.nodes)?,
                        gpus_per_node: get_usize(item, "gpus_per_node", ds.gpus_per_node)?,
                        cpu_replicas: get_usize(item, "cpu_replicas", ds.cpu_replicas)?,
                        wan,
                    });
                }
                sites
            }
        };
        let federation = FederationConfig {
            sites,
            gateway_site: get_str(fe, "gateway_site", &d.federation.gateway_site)?,
            rebalance_interval: get_duration(
                fe,
                "rebalance_interval",
                d.federation.rebalance_interval,
            )?,
            spillover_queue_depth: get_f64(
                fe,
                "spillover_queue_depth",
                d.federation.spillover_queue_depth,
            )?,
        };

        let mon = root.get("monitoring").unwrap_or(&empty);
        check_keys(mon, keys::MONITORING, "monitoring")?;
        let monitoring = MonitoringConfig {
            listen: get_str(mon, "listen", &d.monitoring.listen)?,
            scrape_interval: get_duration(mon, "scrape_interval", d.monitoring.scrape_interval)?,
            retention: get_duration(mon, "retention", d.monitoring.retention)?,
            tracing: get_bool(mon, "tracing", d.monitoring.tracing)?,
        };

        let mp = root.get("model_placement").unwrap_or(&empty);
        check_keys(mp, keys::MODEL_PLACEMENT, "model_placement")?;
        let model_placement = ModelPlacementConfig {
            policy: match mp.get("policy") {
                None => d.model_placement.policy,
                Some(x) => PlacementPolicy::parse(
                    x.as_str().context("'policy' must be a string")?,
                )?,
            },
            memory_budget_mb: get_f64(mp, "memory_budget_mb", d.model_placement.memory_budget_mb)?,
            load_threshold: get_f64(mp, "load_threshold", d.model_placement.load_threshold)?,
            unload_threshold: get_f64(mp, "unload_threshold", d.model_placement.unload_threshold)?,
            cooldown: get_duration(mp, "cooldown", d.model_placement.cooldown)?,
            demand_window: get_duration(mp, "demand_window", d.model_placement.demand_window)?,
            min_replicas_per_model: get_usize(
                mp,
                "min_replicas_per_model",
                d.model_placement.min_replicas_per_model,
            )?,
            load_delay: get_duration(mp, "load_delay", d.model_placement.load_delay)?,
        };

        let eg = root.get("engines").unwrap_or(&empty);
        check_keys(eg, keys::ENGINES, "engines")?;
        let engines = EnginesConfig {
            default_backend: get_str(eg, "default_backend", &d.engines.default_backend)?,
            cpu_replicas: get_usize(eg, "cpu_replicas", d.engines.cpu_replicas)?,
            cpu_max_replicas: get_usize(eg, "cpu_max_replicas", d.engines.cpu_max_replicas)?,
            onnx_slowdown: get_f64(eg, "onnx_slowdown", d.engines.onnx_slowdown)?,
            onnx_load_multiplier: get_f64(
                eg,
                "onnx_load_multiplier",
                d.engines.onnx_load_multiplier,
            )?,
            onnx_memory_multiplier: get_f64(
                eg,
                "onnx_memory_multiplier",
                d.engines.onnx_memory_multiplier,
            )?,
        };

        let ob = root.get("observability").unwrap_or(&empty);
        check_keys(ob, keys::OBSERVABILITY, "observability")?;
        let slos = match ob.get("slos") {
            None => Vec::new(),
            Some(list) => {
                let items = list
                    .as_seq()
                    .context("'observability.slos' must be a sequence")?;
                let mut slos = Vec::new();
                for item in items {
                    check_keys(item, keys::OBSERVABILITY_SLO, "observability.slos[]")?;
                    let ds = SloConfig::default();
                    slos.push(SloConfig {
                        model: get_str(item, "model", "")?,
                        latency_p99: get_duration(item, "latency_p99", ds.latency_p99)?,
                        error_budget: get_f64(item, "error_budget", ds.error_budget)?,
                    });
                }
                slos
            }
        };
        let observability = ObservabilityConfig {
            trace_sample_rate: get_f64(
                ob,
                "trace_sample_rate",
                d.observability.trace_sample_rate,
            )?,
            trace_capacity: get_usize(ob, "trace_capacity", d.observability.trace_capacity)?,
            flight_recorder_capacity: get_usize(
                ob,
                "flight_recorder_capacity",
                d.observability.flight_recorder_capacity,
            )?,
            explain_horizon: get_duration(ob, "explain_horizon", d.observability.explain_horizon)?,
            slo_fast_window: get_duration(ob, "slo_fast_window", d.observability.slo_fast_window)?,
            slo_slow_window: get_duration(ob, "slo_slow_window", d.observability.slo_slow_window)?,
            slo_eval_interval: get_duration(
                ob,
                "slo_eval_interval",
                d.observability.slo_eval_interval,
            )?,
            slo_burn_threshold: get_f64(
                ob,
                "slo_burn_threshold",
                d.observability.slo_burn_threshold,
            )?,
            slos,
            rollback_latency_factor: get_f64(
                ob,
                "rollback_latency_factor",
                d.observability.rollback_latency_factor,
            )?,
            rollback_error_margin: get_f64(
                ob,
                "rollback_error_margin",
                d.observability.rollback_error_margin,
            )?,
            rollback_min_requests: get_usize(
                ob,
                "rollback_min_requests",
                d.observability.rollback_min_requests as usize,
            )? as u64,
        };

        let cfg = DeploymentConfig {
            name,
            server,
            gateway,
            rpc,
            autoscaler,
            cluster,
            federation,
            monitoring,
            model_placement,
            engines,
            observability,
            time_scale,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("deployment name must not be empty");
        }
        if self.server.models.is_empty() {
            bail!("server.models must not be empty");
        }
        for m in &self.server.models {
            if m.name.is_empty() {
                bail!("model name must not be empty");
            }
            if m.name.contains('@') {
                bail!(
                    "model name '{}' must not contain '@' (reserved for \
                     versioned serving names like 'name@v2')",
                    m.name
                );
            }
            if m.preferred_batch == 0 {
                bail!("model '{}' preferred_batch must be >= 1", m.name);
            }
        }
        if self.server.replicas == 0 {
            bail!("server.replicas must be >= 1");
        }
        if self.server.queue_capacity == 0 {
            bail!("server.queue_capacity must be >= 1");
        }
        if self.server.util_window <= 0.0 {
            bail!("server.util_window must be > 0");
        }
        if self.rpc.pool_size == 0 {
            bail!("rpc.pool_size must be >= 1");
        }
        if self.rpc.io_timeout.is_zero() {
            bail!(
                "rpc.io_timeout must be > 0 (it is the hung-backend bound; \
                 a zero timeout would fail every pooled request immediately)"
            );
        }
        if self.rpc.remote_dispatch && self.rpc.dispatch_threads == 0 {
            bail!(
                "rpc.remote_dispatch requires rpc.dispatch_threads >= 1: \
                 instance rpc endpoints demultiplex the gateway's pipelined \
                 sessions, which needs dispatch threads"
            );
        }
        let pr = &self.server.priorities;
        for model in pr.models.keys() {
            if !self.server.models.iter().any(|m| &m.name == model) {
                bail!(
                    "server.priorities.models names '{model}', which is not in \
                     server.models"
                );
            }
        }
        if !(0.0..1.0).contains(&pr.bulk_reserve) {
            bail!("server.priorities.bulk_reserve must be in [0, 1)");
        }
        if !(pr.bulk_pressure_factor > 0.0 && pr.bulk_pressure_factor <= 1.0) {
            bail!(
                "server.priorities.bulk_pressure_factor must be in (0, 1] \
                 (bulk sheds first at the pressure gate)"
            );
        }
        if pr.critical_pressure_factor < 1.0 {
            bail!(
                "server.priorities.critical_pressure_factor must be >= 1 \
                 (critical sheds last at the pressure gate)"
            );
        }
        for m in &self.server.models {
            if m.service_model.service_secs(1) <= 0.0 {
                bail!("model '{}' service_model must have positive service time", m.name);
            }
        }
        // Multi-backend engine layer.
        let eg = &self.engines;
        if !BACKEND_NAMES.contains(&eg.default_backend.as_str()) {
            bail!(
                "engines.default_backend '{}' is not a known backend (expected one of: {})",
                eg.default_backend,
                BACKEND_NAMES.join(", ")
            );
        }
        if eg.onnx_slowdown <= 0.0 {
            bail!("engines.onnx_slowdown must be > 0");
        }
        if eg.onnx_load_multiplier <= 0.0 {
            bail!("engines.onnx_load_multiplier must be > 0");
        }
        if !(eg.onnx_memory_multiplier > 0.0 && eg.onnx_memory_multiplier <= 1.0) {
            bail!(
                "engines.onnx_memory_multiplier must be in (0, 1]: the placement \
                 planner budgets with the unscaled footprint, so a multiplier above 1 \
                 could overcommit instance memory"
            );
        }
        if eg.cpu_max_replicas > 0 {
            if eg.cpu_max_replicas < eg.cpu_replicas {
                bail!(
                    "engines.cpu_max_replicas ({}) is below cpu_replicas ({}): the \
                     CPU scaler's ceiling cannot sit under its floor",
                    eg.cpu_max_replicas,
                    eg.cpu_replicas
                );
            }
            if eg.cpu_replicas == 0 {
                bail!(
                    "engines.cpu_max_replicas requires engines.cpu_replicas >= 1: \
                     CPU autoscaling grows an existing CPU group, it does not \
                     bootstrap one from zero"
                );
            }
            if eg.cpu_max_replicas > eg.cpu_replicas && !self.autoscaler.enabled {
                bail!(
                    "engines.cpu_max_replicas above cpu_replicas needs \
                     autoscaler.enabled: true (nothing else drives \
                     Cluster::set_cpu_desired)"
                );
            }
        }
        for m in &self.server.models {
            let mut seen = std::collections::BTreeSet::new();
            for b in &m.backends {
                if !BACKEND_NAMES.contains(&b.as_str()) {
                    bail!(
                        "model '{}' names unknown backend '{}' (expected one of: {})",
                        m.name,
                        b,
                        BACKEND_NAMES.join(", ")
                    );
                }
                if !seen.insert(b.as_str()) {
                    bail!("model '{}' lists backend '{}' twice", m.name, b);
                }
            }
            // A model that cannot run on pjrt is invisible to GPU-class
            // pods; without the modelmesh router the single global
            // balancer would keep sending its requests to instances
            // that cannot serve it.
            if !m.backends.is_empty()
                && !m.backends.iter().any(|b| b == "pjrt")
                && !self.model_placement.mesh_enabled()
            {
                bail!(
                    "model '{}' excludes the pjrt backend, which requires model-aware \
                     routing: set model_placement.policy: dynamic or a \
                     model_placement.memory_budget_mb > 0",
                    m.name
                );
            }
        }
        // Model-version lifecycle (canary routing + rollback).
        for m in &self.server.models {
            let mut versions = std::collections::BTreeSet::new();
            for v in &m.versions {
                if !versions.insert(v.version) {
                    bail!("model '{}' lists version {} twice", m.name, v.version);
                }
                if v.slowdown <= 0.0 {
                    bail!(
                        "model '{}' version {} slowdown must be > 0",
                        m.name,
                        v.version
                    );
                }
            }
            if m.versions.is_empty() {
                if m.incumbent.is_some() || m.canary.is_some() || m.pinned_version.is_some() {
                    bail!(
                        "model '{}' sets incumbent/canary/pinned_version without \
                         listing any versions",
                        m.name
                    );
                }
                continue;
            }
            if !self.model_placement.mesh_enabled() {
                bail!(
                    "model '{}' lists versions, which requires model-aware routing \
                     (make-before-break swaps need per-version placement): set \
                     model_placement.policy: dynamic or a \
                     model_placement.memory_budget_mb > 0",
                    m.name
                );
            }
            let incumbent = m.incumbent.unwrap_or(m.versions[0].version);
            if !versions.contains(&incumbent) {
                bail!(
                    "model '{}' incumbent version {} is not in its versions list",
                    m.name,
                    incumbent
                );
            }
            if let Some(c) = &m.canary {
                if !versions.contains(&c.version) {
                    bail!(
                        "model '{}' canary version {} is not in its versions list",
                        m.name,
                        c.version
                    );
                }
                if c.version == incumbent {
                    bail!(
                        "model '{}' canary version {} is the incumbent — a canary \
                         must be a different version",
                        m.name,
                        c.version
                    );
                }
                if !(c.weight > 0.0 && c.weight < 1.0) {
                    bail!(
                        "model '{}' canary weight must be in (0, 1), got {}",
                        m.name,
                        c.weight
                    );
                }
                let mut prev = 0.0;
                for (i, w) in c.ramp.iter().enumerate() {
                    if !(*w > 0.0 && *w < 1.0) {
                        bail!(
                            "model '{}' canary ramp stage {} must be in (0, 1), got {}",
                            m.name,
                            i,
                            w
                        );
                    }
                    if *w <= prev {
                        bail!(
                            "model '{}' canary ramp must be strictly increasing \
                             (stage {} is {} after {})",
                            m.name,
                            i,
                            w,
                            prev
                        );
                    }
                    prev = *w;
                }
                if !c.ramp.is_empty() && c.ramp_interval.is_zero() {
                    bail!(
                        "model '{}' canary ramp_interval must be > 0 when a ramp \
                         is set",
                        m.name
                    );
                }
                if m.pinned_version.is_some() {
                    bail!(
                        "model '{}' sets both canary and pinned_version; a pin \
                         disables canary routing — choose one",
                        m.name
                    );
                }
            }
            if let Some(p) = m.pinned_version {
                if !versions.contains(&p) {
                    bail!(
                        "model '{}' pinned_version {} is not in its versions list",
                        m.name,
                        p
                    );
                }
            }
        }
        if eg.cpu_replicas > 0 && !self.model_placement.mesh_enabled() {
            bail!(
                "engines.cpu_replicas requires the modelmesh (per-model routing must \
                 follow advertised backends on a heterogeneous fleet): set \
                 model_placement.policy: dynamic or a model_placement.memory_budget_mb > 0"
            );
        }
        // No autoscaler flavor manages CPU capacity yet: the global
        // trigger aggregates the whole fleet but scaling only adds GPU
        // pods, so a saturated CPU-only model would ratchet GPU pods it
        // can never use (per-model mode rejects the combination above).
        if self.autoscaler.enabled && eg.cpu_replicas > 0 {
            for m in &self.server.models {
                if !m.backends.is_empty() && !m.backends.iter().any(|b| b == "pjrt") {
                    bail!(
                        "the autoscaler only scales GPU pods, but model '{}' excludes \
                         the pjrt backend (backends: {:?}): its saturation would drive \
                         GPU scale-ups that can never serve it; disable the autoscaler, \
                         include pjrt in the model's backends, or size \
                         engines.cpu_replicas statically for its load",
                        m.name,
                        m.backends
                    );
                }
            }
        }
        if self.gateway.worker_threads == 0 {
            bail!("gateway.worker_threads must be >= 1");
        }
        if self.gateway.rate_limit_rps < 0.0 {
            bail!("gateway.rate_limit_rps must be >= 0");
        }
        if self.autoscaler.min_replicas == 0 {
            bail!("autoscaler.min_replicas must be >= 1");
        }
        if self.autoscaler.min_replicas > self.autoscaler.max_replicas {
            bail!(
                "autoscaler.min_replicas ({}) > max_replicas ({})",
                self.autoscaler.min_replicas,
                self.autoscaler.max_replicas
            );
        }
        if self.autoscaler.step == 0 {
            bail!("autoscaler.step must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.autoscaler.scale_down_ratio) {
            bail!("autoscaler.scale_down_ratio must be in [0, 1]");
        }
        if self.autoscaler.threshold <= 0.0 {
            bail!("autoscaler.threshold must be > 0");
        }
        let pm = &self.autoscaler.per_model;
        if pm.threshold <= 0.0 {
            bail!("autoscaler.per_model.threshold must be > 0");
        }
        if pm.min_replicas == 0 {
            bail!("autoscaler.per_model.min_replicas must be >= 1");
        }
        if pm.min_replicas > pm.max_replicas {
            bail!(
                "autoscaler.per_model.min_replicas ({}) > max_replicas ({})",
                pm.min_replicas,
                pm.max_replicas
            );
        }
        if pm.enabled {
            if !self.autoscaler.enabled {
                bail!("autoscaler.per_model.enabled requires autoscaler.enabled: true");
            }
            if !self.model_placement.mesh_enabled() {
                bail!(
                    "autoscaler.per_model requires the modelmesh for its demand \
                     signal: set model_placement.policy: dynamic or a \
                     model_placement.memory_budget_mb > 0"
                );
            }
            if pm.max_replicas > self.autoscaler.max_replicas {
                bail!(
                    "autoscaler.per_model.max_replicas ({}) exceeds the shared pod \
                     budget autoscaler.max_replicas ({})",
                    pm.max_replicas,
                    self.autoscaler.max_replicas
                );
            }
            if pm.min_replicas * self.server.models.len() > self.autoscaler.max_replicas {
                bail!(
                    "autoscaler.per_model.min_replicas ({}) x {} models exceeds the \
                     shared pod budget autoscaler.max_replicas ({})",
                    pm.min_replicas,
                    self.server.models.len(),
                    self.autoscaler.max_replicas
                );
            }
            // Per-model scaling spawns GPU-class boot-profile pods: a
            // model that cannot run on pjrt would get dedicated pods
            // that can never serve it while eating the shared budget.
            for m in &self.server.models {
                if !m.backends.is_empty() && !m.backends.iter().any(|b| b == "pjrt") {
                    bail!(
                        "autoscaler.per_model spawns GPU-class pods, but model '{}' \
                         excludes the pjrt backend (backends: {:?}): its dedicated \
                         pods could never serve it; disable per-model scaling or \
                         include pjrt in the model's backends",
                        m.name,
                        m.backends
                    );
                }
            }
        }
        let capacity = self.cluster.nodes * self.cluster.gpus_per_node;
        if self.autoscaler.max_replicas > capacity {
            bail!(
                "autoscaler.max_replicas ({}) exceeds cluster GPU capacity ({} nodes x {} gpus = {})",
                self.autoscaler.max_replicas,
                self.cluster.nodes,
                self.cluster.gpus_per_node,
                capacity
            );
        }
        // CPU pods bind cluster slots for the whole run, so an enabled
        // autoscaler must be able to reach its cap with them in place —
        // otherwise scale-ups park GPU pods in Pending forever.
        if self.autoscaler.enabled
            && self.autoscaler.max_replicas + self.engines.effective_cpu_max() > capacity
        {
            bail!(
                "autoscaler.max_replicas ({}) + the largest CPU group ({}) exceeds \
                 cluster slot capacity ({}): the autoscaler could target more GPU \
                 pods than free slots exist",
                self.autoscaler.max_replicas,
                self.engines.effective_cpu_max(),
                capacity
            );
        }
        if self.server.replicas + self.engines.cpu_replicas > capacity {
            bail!(
                "server.replicas ({}) + engines.cpu_replicas ({}) exceeds cluster \
                 slot capacity ({})",
                self.server.replicas,
                self.engines.cpu_replicas,
                capacity
            );
        }
        if !(0.0..=1.0).contains(&self.cluster.pod_failure_rate) {
            bail!("cluster.pod_failure_rate must be in [0, 1]");
        }
        // Multi-site federation.
        let fed = &self.federation;
        if fed.enabled() {
            if fed.sites.len() < 2 {
                bail!(
                    "federation.sites needs at least 2 sites (one site is just \
                     the single-cluster mode — drop the federation section)"
                );
            }
            if !self.model_placement.mesh_enabled() {
                bail!(
                    "federation requires the modelmesh (site-local placement \
                     drives the warm-capacity signal): set model_placement.policy: \
                     dynamic or a model_placement.memory_budget_mb > 0"
                );
            }
            if !(self.autoscaler.enabled && self.autoscaler.per_model.enabled) {
                bail!(
                    "federation requires autoscaler.per_model.enabled: the global \
                     rebalancer shifts the per-site scalers' pod budgets — with no \
                     site-local per-model scaler there is nothing to rebalance"
                );
            }
            if fed.rebalance_interval.is_zero() {
                bail!("federation.rebalance_interval must be > 0");
            }
            if fed.spillover_queue_depth <= 0.0 {
                bail!("federation.spillover_queue_depth must be > 0");
            }
            if self.engines.cpu_replicas > 0 || self.engines.cpu_max_replicas > 0 {
                bail!(
                    "federation sizes CPU groups per site \
                     (federation.sites[].cpu_replicas); engines.cpu_replicas / \
                     cpu_max_replicas must stay 0 in federated mode"
                );
            }
            let mut names = std::collections::BTreeSet::new();
            for s in &fed.sites {
                if s.name.is_empty() {
                    bail!("federation.sites[] entries need a non-empty 'name'");
                }
                if !names.insert(s.name.as_str()) {
                    bail!("federation.sites lists site '{}' twice", s.name);
                }
            }
            if !fed.gateway_site.is_empty() && !names.contains(fed.gateway_site.as_str()) {
                bail!(
                    "federation.gateway_site '{}' is not a listed site",
                    fed.gateway_site
                );
            }
            let floor = self.autoscaler.per_model.min_replicas * self.server.models.len();
            for s in &fed.sites {
                let cap = s.nodes * s.gpus_per_node;
                if s.replicas == 0 {
                    bail!("federation site '{}' needs replicas >= 1", s.name);
                }
                if s.replicas > s.pod_budget {
                    bail!(
                        "federation site '{}' boots {} replicas over its pod_budget {}",
                        s.name,
                        s.replicas,
                        s.pod_budget
                    );
                }
                // Every site must be able to hold every model's minimum:
                // the rebalancer floors each site's budget there, and
                // outage recovery re-seeds a site at exactly the mins.
                if s.pod_budget < floor {
                    bail!(
                        "federation site '{}' pod_budget ({}) is below the per-model \
                         floor ({} min_replicas x {} models = {}): the site could \
                         not keep every model warm",
                        s.name,
                        s.pod_budget,
                        self.autoscaler.per_model.min_replicas,
                        self.server.models.len(),
                        floor
                    );
                }
                if s.pod_budget + s.cpu_replicas > cap {
                    bail!(
                        "federation site '{}' pod_budget ({}) + cpu_replicas ({}) \
                         exceeds its slot capacity ({} nodes x {} gpus = {})",
                        s.name,
                        s.pod_budget,
                        s.cpu_replicas,
                        s.nodes,
                        s.gpus_per_node,
                        cap
                    );
                }
                for peer in s.wan.keys() {
                    if !names.contains(peer.as_str()) {
                        bail!(
                            "federation site '{}' wan map names unknown site '{}'",
                            s.name,
                            peer
                        );
                    }
                    if peer == &s.name {
                        bail!(
                            "federation site '{}' wan map prices a hop to itself \
                             (local dispatch is free by definition)",
                            s.name
                        );
                    }
                }
            }
        }
        if self.model_placement.memory_budget_mb < 0.0 {
            bail!("model_placement.memory_budget_mb must be >= 0");
        }
        if self.model_placement.load_threshold <= 0.0 {
            bail!("model_placement.load_threshold must be > 0");
        }
        if self.model_placement.unload_threshold < 0.0 {
            bail!("model_placement.unload_threshold must be >= 0");
        }
        if self.model_placement.unload_threshold >= self.model_placement.load_threshold {
            bail!(
                "model_placement.unload_threshold ({}) must be below load_threshold ({}) \
                 (hysteresis band)",
                self.model_placement.unload_threshold,
                self.model_placement.load_threshold
            );
        }
        if self.model_placement.min_replicas_per_model == 0 {
            bail!("model_placement.min_replicas_per_model must be >= 1");
        }
        // Warm-load cost sanity: a load delay at or beyond the whole
        // amortization horizon means a demand-driven load can never pay
        // for itself, silently freezing dynamic placement. Reject the
        // combination instead of freezing.
        if self.model_placement.policy == PlacementPolicy::Dynamic {
            let horizon = self.model_placement.load_cost_horizon();
            for m in &self.server.models {
                let delay = self.effective_load_delay(m);
                if !delay.is_zero() && delay >= horizon {
                    bail!(
                        "model '{}' warm-load delay ({:.1}s) reaches the placement \
                         amortization horizon (max(cooldown, demand_window) = {:.1}s): \
                         dynamic placement could never amortize loading it; lower the \
                         delay or raise model_placement.cooldown / demand_window",
                        m.name,
                        delay.as_secs_f64(),
                        horizon.as_secs_f64()
                    );
                }
            }
        }
        // Observability: tracing + SLO engine.
        let ob = &self.observability;
        if !(0.0..=1.0).contains(&ob.trace_sample_rate) {
            bail!("observability.trace_sample_rate must be in [0, 1]");
        }
        if ob.trace_capacity == 0 {
            bail!("observability.trace_capacity must be >= 1");
        }
        if ob.explain_horizon.is_zero() {
            bail!(
                "observability.explain_horizon must be > 0 (a zero horizon \
                 would make every explain query come back empty)"
            );
        }
        if ob.slo_burn_threshold <= 0.0 {
            bail!("observability.slo_burn_threshold must be > 0");
        }
        if ob.slo_fast_window.is_zero() {
            bail!("observability.slo_fast_window must be > 0");
        }
        if ob.rollback_latency_factor < 1.0 {
            bail!(
                "observability.rollback_latency_factor must be >= 1 (a factor \
                 below 1 would roll back a canary faster than the incumbent)"
            );
        }
        if ob.rollback_error_margin < 0.0 {
            bail!("observability.rollback_error_margin must be >= 0");
        }
        if ob.rollback_min_requests == 0 {
            bail!(
                "observability.rollback_min_requests must be >= 1 (the rollback \
                 comparison needs at least one request per arm)"
            );
        }
        if ob.slo_slow_window < ob.slo_fast_window {
            bail!(
                "observability.slo_slow_window ({:.1}s) must be >= slo_fast_window \
                 ({:.1}s) (the slow window suppresses blips the fast window catches)",
                ob.slo_slow_window.as_secs_f64(),
                ob.slo_fast_window.as_secs_f64()
            );
        }
        if ob.slo_eval_interval.is_zero() {
            bail!("observability.slo_eval_interval must be > 0");
        }
        if ob.slo_eval_interval > ob.slo_fast_window {
            bail!(
                "observability.slo_eval_interval ({:.1}s) must not exceed \
                 slo_fast_window ({:.1}s): the fast window needs at least two \
                 evaluation points to compute a burn rate",
                ob.slo_eval_interval.as_secs_f64(),
                ob.slo_fast_window.as_secs_f64()
            );
        }
        let mut slo_models = std::collections::BTreeSet::new();
        for slo in &ob.slos {
            if !self.server.models.iter().any(|m| m.name == slo.model) {
                bail!(
                    "observability.slos names model '{}', which is not in server.models",
                    slo.model
                );
            }
            if !slo_models.insert(slo.model.as_str()) {
                bail!("observability.slos lists model '{}' twice", slo.model);
            }
            if slo.latency_p99.is_zero() {
                bail!("observability.slos model '{}': latency_p99 must be > 0", slo.model);
            }
            if !(slo.error_budget > 0.0 && slo.error_budget <= 1.0) {
                bail!(
                    "observability.slos model '{}': error_budget must be in (0, 1]",
                    slo.model
                );
            }
        }
        if self.time_scale <= 0.0 {
            bail!("time_scale must be > 0");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        DeploymentConfig::default().validate().unwrap();
    }

    #[test]
    fn empty_yaml_gives_defaults() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert_eq!(cfg, DeploymentConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let text = r#"
name: test-deploy
time_scale: 10.0
server:
  replicas: 2
  repository: artifacts
  startup_delay: 1.5
  models:
    - name: particlenet
      max_queue_delay: 0.002
      preferred_batch: 8
    - name: icecube_cnn
gateway:
  listen: 127.0.0.1:9001
  lb_policy: least_connection
  rate_limit_rps: 500
  auth_secret: hunter2
autoscaler:
  enabled: true
  threshold: 0.08
  min_replicas: 1
  max_replicas: 10
cluster:
  nodes: 5
  gpus_per_node: 2
monitoring:
  scrape_interval: 0.5
  tracing: true
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        assert_eq!(cfg.name, "test-deploy");
        assert_eq!(cfg.server.replicas, 2);
        assert_eq!(cfg.server.models.len(), 2);
        assert_eq!(cfg.server.models[0].preferred_batch, 8);
        assert_eq!(cfg.server.models[1].name, "icecube_cnn");
        assert_eq!(cfg.gateway.lb_policy, LbPolicy::LeastConnection);
        assert_eq!(cfg.gateway.auth_secret.as_deref(), Some("hunter2"));
        assert!(cfg.autoscaler.enabled);
        assert_eq!(cfg.autoscaler.max_replicas, 10);
        assert_eq!(cfg.cluster.nodes, 5);
        assert!((cfg.monitoring.scrape_interval.as_secs_f64() - 0.5).abs() < 1e-9);
        assert!(cfg.monitoring.tracing);
        assert_eq!(cfg.time_scale, 10.0);
    }

    #[test]
    fn unknown_key_rejected() {
        let e = DeploymentConfig::from_yaml("gateway:\n  lb_polcy: round_robin\n").unwrap_err();
        assert!(e.to_string().contains("lb_polcy"), "{e}");
    }

    #[test]
    fn unknown_root_key_rejected() {
        assert!(DeploymentConfig::from_yaml("severs:\n  replicas: 2\n").is_err());
    }

    #[test]
    fn bad_lb_policy_rejected() {
        let e = DeploymentConfig::from_yaml("gateway:\n  lb_policy: fastest\n").unwrap_err();
        assert!(e.to_string().contains("fastest"));
    }

    #[test]
    fn min_gt_max_rejected() {
        let text = "autoscaler:\n  min_replicas: 5\n  max_replicas: 2\n";
        assert!(DeploymentConfig::from_yaml(text).is_err());
    }

    #[test]
    fn max_replicas_capped_by_cluster() {
        let text = "autoscaler:\n  max_replicas: 100\ncluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("capacity"), "{e}");
    }

    #[test]
    fn negative_duration_rejected() {
        assert!(DeploymentConfig::from_yaml("server:\n  startup_delay: -1\n").is_err());
    }

    #[test]
    fn zero_replicas_rejected() {
        assert!(DeploymentConfig::from_yaml("server:\n  replicas: 0\n").is_err());
    }

    #[test]
    fn null_auth_secret_is_none() {
        let cfg = DeploymentConfig::from_yaml("gateway:\n  auth_secret: null\n").unwrap();
        assert!(cfg.gateway.auth_secret.is_none());
    }

    #[test]
    fn execution_mode_parses() {
        let cfg = DeploymentConfig::from_yaml("server:\n  execution: simulated\n").unwrap();
        assert_eq!(cfg.server.execution, ExecutionMode::Simulated);
        assert!(DeploymentConfig::from_yaml("server:\n  execution: warp_speed\n").is_err());
        for m in [ExecutionMode::Real, ExecutionMode::Simulated] {
            assert_eq!(ExecutionMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn service_model_parses() {
        let text = "server:\n  models:\n    - name: particlenet\n      service_model:\n        base: 0.01\n        per_row: 0.002\n";
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let sm = cfg.server.models[0].service_model;
        assert!((sm.service_secs(4) - 0.018).abs() < 1e-9);
    }

    #[test]
    fn service_model_unknown_key_rejected() {
        let text = "server:\n  models:\n    - name: pn\n      service_model:\n        bse: 0.01\n";
        assert!(DeploymentConfig::from_yaml(text).is_err());
    }

    #[test]
    fn batch_mode_parses() {
        let cfg = DeploymentConfig::from_yaml("server:\n  batch_mode: fifo\n").unwrap();
        assert_eq!(cfg.server.batch_mode, BatchMode::Fifo);
        // affinity is the default
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert_eq!(cfg.server.batch_mode, BatchMode::Affinity);
        assert!(DeploymentConfig::from_yaml("server:\n  batch_mode: lifo\n").is_err());
        for m in [BatchMode::Fifo, BatchMode::Affinity] {
            assert_eq!(BatchMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn priorities_parse_and_resolve() {
        let text = "server:\n  models:\n    - name: particlenet\n    - name: icecube_cnn\n  \
                    priorities:\n    default: bulk\n    models:\n      particlenet: critical\n    \
                    tokens:\n      trigger-farm: critical\n      reprocessing: bulk\n    \
                    bulk_reserve: 0.5\n";
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let pr = &cfg.server.priorities;
        assert_eq!(pr.default, Priority::Bulk);
        assert_eq!(pr.models["particlenet"], Priority::Critical);
        assert_eq!(pr.tokens["trigger-farm"], Priority::Critical);
        assert_eq!(pr.bulk_reserve, 0.5);
        // resolution order: explicit > token > model > default
        assert_eq!(
            pr.resolve(Some(Priority::Standard), "trigger-farm", "particlenet"),
            Priority::Standard
        );
        assert_eq!(pr.resolve(None, "reprocessing", "particlenet"), Priority::Bulk);
        assert_eq!(pr.resolve(None, "anon", "particlenet"), Priority::Critical);
        assert_eq!(pr.resolve(None, "anon", "icecube_cnn"), Priority::Bulk);
        // pressure factors: standard is always 1.0
        assert_eq!(pr.pressure_factor(Priority::Standard), 1.0);
        assert!(pr.pressure_factor(Priority::Bulk) <= 1.0);
        assert!(pr.pressure_factor(Priority::Critical) >= 1.0);
    }

    #[test]
    fn priorities_default_is_standard() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        let pr = &cfg.server.priorities;
        assert_eq!(pr.default, Priority::Standard);
        assert!(pr.models.is_empty() && pr.tokens.is_empty());
        assert_eq!(pr.resolve(None, "any", "any"), Priority::Standard);
    }

    #[test]
    fn priorities_bad_values_rejected() {
        // unknown class name
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    default: urgent\n"
        )
        .is_err());
        // unknown key (typo protection)
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    defalt: bulk\n"
        )
        .is_err());
        // per-model default for an unserved model
        let e = DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n  priorities:\n    models:\n      \
             nope: critical\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("nope"), "{e}");
        // reserve / factor bounds
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    bulk_reserve: 1.5\n"
        )
        .is_err());
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    bulk_pressure_factor: 2.0\n"
        )
        .is_err());
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    critical_pressure_factor: 0.5\n"
        )
        .is_err());
    }

    #[test]
    fn load_delay_parses_and_inherits() {
        let text = "server:\n  models:\n    - name: particlenet\n      load_delay: 2.5\n    \
                    - name: icecube_cnn\nmodel_placement:\n  load_delay: 1.0\n";
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        assert_eq!(cfg.server.models[0].load_delay, Some(Duration::from_secs_f64(2.5)));
        assert_eq!(cfg.server.models[1].load_delay, None);
        // per-model override wins, absent inherits the global default
        assert_eq!(
            cfg.effective_load_delay(&cfg.server.models[0]),
            Duration::from_secs_f64(2.5)
        );
        assert_eq!(
            cfg.effective_load_delay(&cfg.server.models[1]),
            Duration::from_secs_f64(1.0)
        );
        // negative delays rejected like every duration
        assert!(DeploymentConfig::from_yaml("model_placement:\n  load_delay: -1\n").is_err());
    }

    #[test]
    fn load_delay_at_horizon_rejected_for_dynamic() {
        // horizon = max(cooldown 10, demand_window 15) = 15 s (defaults):
        // a 20 s load could never amortize under dynamic placement.
        let e = DeploymentConfig::from_yaml(
            "model_placement:\n  policy: dynamic\n  load_delay: 20\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("horizon"), "{e}");
        // below the horizon is legal...
        DeploymentConfig::from_yaml("model_placement:\n  policy: dynamic\n  load_delay: 5\n")
            .unwrap();
        // ...and static placement never plans demand-driven loads, so it
        // tolerates any delay.
        DeploymentConfig::from_yaml("model_placement:\n  load_delay: 20\n").unwrap();
    }

    #[test]
    fn load_cost_horizon_is_max_of_cooldown_and_window() {
        let cfg = DeploymentConfig::from_yaml(
            "model_placement:\n  cooldown: 30\n  demand_window: 8\n",
        )
        .unwrap();
        assert_eq!(cfg.model_placement.load_cost_horizon(), Duration::from_secs(30));
    }

    #[test]
    fn lb_policy_roundtrip_names() {
        for p in [LbPolicy::RoundRobin, LbPolicy::LeastConnection, LbPolicy::UtilizationAware, LbPolicy::Random] {
            assert_eq!(LbPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn per_model_scaling_parses() {
        let text = r#"
server:
  models:
    - name: particlenet
    - name: icecube_cnn
autoscaler:
  enabled: true
  max_replicas: 6
  per_model:
    enabled: true
    threshold: 200
    min_replicas: 1
    max_replicas: 5
model_placement:
  policy: dynamic
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let pm = &cfg.autoscaler.per_model;
        assert!(pm.enabled);
        assert_eq!(pm.threshold, 200.0);
        assert_eq!(pm.min_replicas, 1);
        assert_eq!(pm.max_replicas, 5);
    }

    #[test]
    fn per_model_scaling_defaults_off() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert!(!cfg.autoscaler.per_model.enabled);
    }

    #[test]
    fn per_model_scaling_bad_values_rejected() {
        // needs the parent autoscaler on
        let e = DeploymentConfig::from_yaml(
            "autoscaler:\n  per_model:\n    enabled: true\nmodel_placement:\n  policy: dynamic\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("autoscaler.enabled"), "{e}");
        // needs the modelmesh demand signal
        let e = DeploymentConfig::from_yaml(
            "autoscaler:\n  enabled: true\n  per_model:\n    enabled: true\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("modelmesh"), "{e}");
        // per-model cap cannot exceed the shared budget
        let text = "autoscaler:\n  enabled: true\n  max_replicas: 4\n  per_model:\n    \
                    enabled: true\n    max_replicas: 8\nmodel_placement:\n  policy: dynamic\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("budget"), "{e}");
        // inverted per-model bounds
        assert!(DeploymentConfig::from_yaml(
            "autoscaler:\n  per_model:\n    min_replicas: 3\n    max_replicas: 2\n"
        )
        .is_err());
        // typo protection inside the subsection
        assert!(
            DeploymentConfig::from_yaml("autoscaler:\n  per_model:\n    treshold: 5\n").is_err()
        );
    }

    #[test]
    fn per_model_floors_capped_by_budget() {
        let text = r#"
server:
  models:
    - name: particlenet
    - name: icecube_cnn
    - name: cms_transformer
autoscaler:
  enabled: true
  max_replicas: 5
  per_model:
    enabled: true
    min_replicas: 2
    max_replicas: 4
model_placement:
  policy: dynamic
"#;
        // 3 models x floor 2 = 6 > budget 5
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("models"), "{e}");
    }

    #[test]
    fn all_preset_configs_parse_and_validate() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        let mut seen = 0;
        for entry in std::fs::read_dir(dir).expect("configs/ must exist") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("yaml") {
                continue;
            }
            DeploymentConfig::from_file(&path)
                .unwrap_or_else(|e| panic!("preset {} rejected: {e:#}", path.display()));
            seen += 1;
        }
        assert!(seen >= 8, "expected the preset set, found {seen} yaml files");
    }

    #[test]
    fn config_doc_covers_every_schema_field() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/CONFIG.md");
        let doc = std::fs::read_to_string(path).expect("docs/CONFIG.md must exist");
        for (section, section_keys) in keys::SECTIONS {
            for key in *section_keys {
                assert!(
                    doc.contains(&format!("`{key}`")),
                    "docs/CONFIG.md is missing `{key}` (section {section}); \
                     keep the reference in sync with config/schema.rs"
                );
            }
        }
    }

    #[test]
    fn engines_defaults_are_homogeneous_pjrt() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert_eq!(cfg.engines, EnginesConfig::default());
        assert_eq!(cfg.engines.default_backend, "pjrt");
        assert_eq!(cfg.engines.cpu_replicas, 0);
        assert!(cfg.server.models[0].backends.is_empty());
    }

    #[test]
    fn engines_section_parses() {
        let text = r#"
server:
  models:
    - name: particlenet
      backends: [pjrt, onnx-sim]
    - name: icecube_cnn
      backends: [onnx-sim]
engines:
  default_backend: pjrt
  cpu_replicas: 2
  onnx_slowdown: 2.5
  onnx_load_multiplier: 0.25
  onnx_memory_multiplier: 0.75
model_placement:
  policy: dynamic
cluster:
  nodes: 2
  gpus_per_node: 2
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        assert_eq!(cfg.server.models[0].backends, vec!["pjrt", "onnx-sim"]);
        assert_eq!(cfg.server.models[1].backends, vec!["onnx-sim"]);
        assert_eq!(cfg.engines.cpu_replicas, 2);
        assert_eq!(cfg.engines.onnx_slowdown, 2.5);
        assert_eq!(cfg.engines.onnx_load_multiplier, 0.25);
        assert_eq!(cfg.engines.onnx_memory_multiplier, 0.75);
    }

    #[test]
    fn engines_bad_values_rejected() {
        // unknown default backend
        let e = DeploymentConfig::from_yaml("engines:\n  default_backend: tensorrt\n")
            .unwrap_err();
        assert!(e.to_string().contains("tensorrt"), "{e}");
        // non-positive multipliers
        assert!(DeploymentConfig::from_yaml("engines:\n  onnx_slowdown: 0\n").is_err());
        assert!(DeploymentConfig::from_yaml("engines:\n  onnx_load_multiplier: 0\n").is_err());
        // memory multiplier above 1 would overcommit planned budgets
        let e = DeploymentConfig::from_yaml("engines:\n  onnx_memory_multiplier: 1.5\n")
            .unwrap_err();
        assert!(e.to_string().contains("overcommit"), "{e}");
        // typo protection inside the section
        assert!(DeploymentConfig::from_yaml("engines:\n  cpu_replcas: 1\n").is_err());
    }

    #[test]
    fn model_backends_validated() {
        // unknown backend name
        let e = DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      backends: [cuda]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("cuda"), "{e}");
        // duplicates
        assert!(DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      backends: [pjrt, pjrt]\n",
        )
        .is_err());
        // a pjrt-excluding model needs model-aware routing...
        let e = DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      backends: [onnx-sim]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("model-aware routing"), "{e}");
        // ...and is legal once the mesh is on
        DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      backends: [onnx-sim]\n\
             model_placement:\n  policy: dynamic\n",
        )
        .unwrap();
    }

    #[test]
    fn cpu_replicas_need_mesh_and_fit_capacity() {
        let e = DeploymentConfig::from_yaml("engines:\n  cpu_replicas: 1\n").unwrap_err();
        assert!(e.to_string().contains("modelmesh"), "{e}");
        // cpu pods occupy cluster slots like gpu pods
        let text = "server:\n  replicas: 3\nengines:\n  cpu_replicas: 2\n\
                    model_placement:\n  policy: dynamic\ncluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("capacity"), "{e}");
        // within capacity it validates
        let text = "server:\n  replicas: 2\nengines:\n  cpu_replicas: 2\n\
                    model_placement:\n  policy: dynamic\ncluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        DeploymentConfig::from_yaml(text).unwrap();
    }

    #[test]
    fn autoscaler_budget_counts_cpu_pods() {
        // Capacity 4, cpu pods pin 2 slots: an enabled autoscaler whose
        // cap could target more GPU pods than the free slots is rejected.
        let text = "server:\n  replicas: 2\nengines:\n  cpu_replicas: 2\n\
                    autoscaler:\n  enabled: true\n  max_replicas: 4\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("free slots"), "{e}");
        // A reachable cap validates...
        let text = "server:\n  replicas: 2\nengines:\n  cpu_replicas: 2\n\
                    autoscaler:\n  enabled: true\n  max_replicas: 2\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        DeploymentConfig::from_yaml(text).unwrap();
        // ...and a disabled autoscaler's cap is inert, so cpu pods may
        // fill the slots it nominally claims.
        let text = "server:\n  replicas: 2\nengines:\n  cpu_replicas: 2\n\
                    autoscaler:\n  max_replicas: 4\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 2\n  gpus_per_node: 2\n";
        DeploymentConfig::from_yaml(text).unwrap();
    }

    #[test]
    fn per_model_scaling_rejects_pjrt_excluding_models() {
        // Per-model scaling spawns GPU-class boot-profile pods: a
        // CPU-only model would get dedicated pods that can never serve
        // it while eating the shared budget. (No CPU pods here, so the
        // broader autoscaler-vs-CPU-only check does not fire first.)
        let text = "server:\n  models:\n    - name: particlenet\n    - name: icecube_cnn\n      \
                    backends: [onnx-sim]\n\
                    autoscaler:\n  enabled: true\n  max_replicas: 6\n  per_model:\n    \
                    enabled: true\nmodel_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 4\n  gpus_per_node: 2\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("GPU-class pods"), "{e}");
        // The same fleet without per-model scaling is legal.
        let text = "server:\n  models:\n    - name: particlenet\n    - name: icecube_cnn\n      \
                    backends: [onnx-sim]\nengines:\n  cpu_replicas: 1\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 4\n  gpus_per_node: 2\n";
        DeploymentConfig::from_yaml(text).unwrap();
    }

    #[test]
    fn global_autoscaler_rejects_cpu_only_models_on_mixed_fleets() {
        // A saturated CPU-only model would ratchet GPU scale-ups that
        // can never serve it: rejected while the autoscaler is on...
        let text = "server:\n  models:\n    - name: particlenet\n    - name: icecube_cnn\n      \
                    backends: [onnx-sim]\nengines:\n  cpu_replicas: 1\n\
                    autoscaler:\n  enabled: true\n  max_replicas: 6\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 4\n  gpus_per_node: 2\n";
        let e = DeploymentConfig::from_yaml(text).unwrap_err();
        assert!(e.to_string().contains("only scales GPU pods"), "{e}");
        // ...and legal with the autoscaler off (statically sized fleet).
        let text = "server:\n  models:\n    - name: particlenet\n    - name: icecube_cnn\n      \
                    backends: [onnx-sim]\nengines:\n  cpu_replicas: 1\n\
                    model_placement:\n  policy: dynamic\n\
                    cluster:\n  nodes: 4\n  gpus_per_node: 2\n";
        DeploymentConfig::from_yaml(text).unwrap();
    }

    #[test]
    fn max_bulk_wait_parses() {
        let cfg = DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    max_bulk_wait: 1.5\n",
        )
        .unwrap();
        assert_eq!(cfg.server.priorities.max_bulk_wait, Duration::from_secs_f64(1.5));
        // default: aging disabled
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert!(cfg.server.priorities.max_bulk_wait.is_zero());
        // negative rejected like every duration
        assert!(DeploymentConfig::from_yaml(
            "server:\n  priorities:\n    max_bulk_wait: -1\n"
        )
        .is_err());
    }

    #[test]
    fn model_placement_defaults_are_legacy() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        assert_eq!(cfg.model_placement.policy, PlacementPolicy::Static);
        assert_eq!(cfg.model_placement.memory_budget_mb, 0.0);
        assert!(!cfg.model_placement.mesh_enabled());
    }

    #[test]
    fn model_placement_parses() {
        let text = r#"
model_placement:
  policy: dynamic
  memory_budget_mb: 0.25
  load_threshold: 120
  unload_threshold: 30
  cooldown: 2.5
  demand_window: 8
  min_replicas_per_model: 1
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let mp = &cfg.model_placement;
        assert_eq!(mp.policy, PlacementPolicy::Dynamic);
        assert!(mp.mesh_enabled());
        assert_eq!(mp.budget_bytes(), 250_000);
        assert_eq!(mp.load_threshold, 120.0);
        assert_eq!(mp.unload_threshold, 30.0);
        assert!((mp.cooldown.as_secs_f64() - 2.5).abs() < 1e-9);
        assert!((mp.demand_window.as_secs_f64() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn model_placement_static_with_budget_enables_mesh() {
        let cfg = DeploymentConfig::from_yaml(
            "model_placement:\n  policy: static\n  memory_budget_mb: 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.model_placement.policy, PlacementPolicy::Static);
        assert!(cfg.model_placement.mesh_enabled());
    }

    #[test]
    fn model_placement_bad_values_rejected() {
        assert!(DeploymentConfig::from_yaml("model_placement:\n  policy: magic\n").is_err());
        // inverted hysteresis band
        assert!(DeploymentConfig::from_yaml(
            "model_placement:\n  load_threshold: 10\n  unload_threshold: 20\n"
        )
        .is_err());
        assert!(DeploymentConfig::from_yaml(
            "model_placement:\n  min_replicas_per_model: 0\n"
        )
        .is_err());
        // typo protection
        assert!(DeploymentConfig::from_yaml("model_placement:\n  polcy: static\n").is_err());
        for p in [PlacementPolicy::Static, PlacementPolicy::Dynamic] {
            assert_eq!(PlacementPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn observability_defaults() {
        let cfg = DeploymentConfig::from_yaml("").unwrap();
        let ob = &cfg.observability;
        assert_eq!(ob.trace_sample_rate, 1.0);
        assert_eq!(ob.trace_capacity, 65536);
        assert_eq!(ob.slo_fast_window, Duration::from_secs(300));
        assert_eq!(ob.slo_slow_window, Duration::from_secs(3600));
        assert!(ob.slos.is_empty());
    }

    #[test]
    fn observability_parses() {
        let text = r#"
observability:
  trace_sample_rate: 0.25
  trace_capacity: 1024
  slo_fast_window: 60
  slo_slow_window: 600
  slo_eval_interval: 2
  slo_burn_threshold: 4
  slos:
    - model: particlenet
      latency_p99: 0.2
      error_budget: 0.05
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let ob = &cfg.observability;
        assert_eq!(ob.trace_sample_rate, 0.25);
        assert_eq!(ob.trace_capacity, 1024);
        assert_eq!(ob.slo_burn_threshold, 4.0);
        assert_eq!(ob.slos.len(), 1);
        assert_eq!(ob.slos[0].model, "particlenet");
        assert!((ob.slos[0].latency_p99.as_secs_f64() - 0.2).abs() < 1e-9);
        assert_eq!(ob.slos[0].error_budget, 0.05);
    }

    #[test]
    fn observability_bad_values_rejected() {
        assert!(
            DeploymentConfig::from_yaml("observability:\n  trace_sample_rate: 1.5\n").is_err()
        );
        assert!(DeploymentConfig::from_yaml("observability:\n  trace_capacity: 0\n").is_err());
        assert!(
            DeploymentConfig::from_yaml("observability:\n  slo_burn_threshold: 0\n").is_err()
        );
        // slow window below fast window breaks the multi-window rule
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  slo_fast_window: 120\n  slo_slow_window: 60\n"
        )
        .is_err());
        // eval interval must fit inside the fast window
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  slo_fast_window: 10\n  slo_eval_interval: 30\n"
        )
        .is_err());
        // SLO for an unknown model
        let e = DeploymentConfig::from_yaml(
            "observability:\n  slos:\n    - model: nope\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("not in server.models"), "{e}");
        // duplicate SLO entries
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  slos:\n    - model: particlenet\n    - model: particlenet\n"
        )
        .is_err());
        // bad budget
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  slos:\n    - model: particlenet\n      error_budget: 0\n"
        )
        .is_err());
        // typo protection
        assert!(
            DeploymentConfig::from_yaml("observability:\n  trace_sample_rte: 0.5\n").is_err()
        );
    }

    #[test]
    fn model_versions_parse() {
        let text = r#"
server:
  models:
    - name: particlenet
      versions:
        - 1
        - version: 2
          slowdown: 3.5
      incumbent: 1
      canary:
        version: 2
        weight: 0.1
model_placement:
  policy: dynamic
observability:
  rollback_latency_factor: 4
  rollback_error_margin: 0.1
  rollback_min_requests: 5
"#;
        let cfg = DeploymentConfig::from_yaml(text).unwrap();
        let m = &cfg.server.models[0];
        assert_eq!(
            m.versions,
            vec![
                VersionSpec { version: 1, slowdown: 1.0 },
                VersionSpec { version: 2, slowdown: 3.5 },
            ]
        );
        assert_eq!(m.incumbent_version(), Some(1));
        assert_eq!(
            m.canary,
            Some(CanaryConfig { version: 2, weight: 0.1, ..CanaryConfig::default() })
        );
        assert_eq!(m.pinned_version, None);
        let ob = &cfg.observability;
        assert_eq!(ob.rollback_latency_factor, 4.0);
        assert_eq!(ob.rollback_error_margin, 0.1);
        assert_eq!(ob.rollback_min_requests, 5);
        // implicit incumbent = first listed version
        let cfg = DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      versions: [3, 4]\n\
             model_placement:\n  policy: dynamic\n",
        )
        .unwrap();
        assert_eq!(cfg.server.models[0].incumbent_version(), Some(3));
        // unversioned models stay unversioned
        let cfg = DeploymentConfig::from_yaml("server:\n  models:\n    - name: particlenet\n")
            .unwrap();
        assert_eq!(cfg.server.models[0].incumbent_version(), None);
    }

    #[test]
    fn model_versions_bad_values_rejected() {
        let versioned = |tail: &str| {
            format!(
                "server:\n  models:\n    - name: particlenet\n      versions: [1, 2]\n{tail}\
                 model_placement:\n  policy: dynamic\n"
            )
        };
        // '@' is reserved for versioned serving names
        assert!(
            DeploymentConfig::from_yaml("server:\n  models:\n    - name: pn@v1\n").is_err()
        );
        // versions require the modelmesh (make-before-break placement)
        assert!(DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      versions: [1, 2]\n"
        )
        .is_err());
        // duplicate version numbers
        assert!(DeploymentConfig::from_yaml(&versioned("")
            .replace("[1, 2]", "[1, 1]"))
        .is_err());
        // incumbent outside the versions list
        assert!(DeploymentConfig::from_yaml(&versioned("      incumbent: 9\n")).is_err());
        // canary must name a registered, non-incumbent version
        assert!(DeploymentConfig::from_yaml(&versioned(
            "      canary:\n        version: 9\n        weight: 0.5\n"
        ))
        .is_err());
        assert!(DeploymentConfig::from_yaml(&versioned(
            "      canary:\n        version: 1\n        weight: 0.5\n"
        ))
        .is_err());
        // canary weight must be in (0, 1)
        assert!(DeploymentConfig::from_yaml(&versioned(
            "      canary:\n        version: 2\n        weight: 1.5\n"
        ))
        .is_err());
        // canary + pin are mutually exclusive
        assert!(DeploymentConfig::from_yaml(&versioned(
            "      canary:\n        version: 2\n        weight: 0.5\n      pinned_version: 1\n"
        ))
        .is_err());
        // pin outside the versions list
        assert!(DeploymentConfig::from_yaml(&versioned("      pinned_version: 7\n")).is_err());
        // slowdown must be positive
        assert!(DeploymentConfig::from_yaml(&versioned("")
            .replace("[1, 2]", "[{version: 1, slowdown: 0}]"))
        .is_err());
        // version knobs without versions
        assert!(DeploymentConfig::from_yaml(
            "server:\n  models:\n    - name: particlenet\n      incumbent: 1\n"
        )
        .is_err());
        // rollback knobs are validated
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  rollback_latency_factor: 0.5\n"
        )
        .is_err());
        assert!(DeploymentConfig::from_yaml(
            "observability:\n  rollback_min_requests: 0\n"
        )
        .is_err());
    }
}
