//! Declarative configuration — the Helm-values analogue.
//!
//! SuperSONIC is distributed as a Helm chart whose `values.yaml` drives the
//! whole deployment. This module reproduces that surface: a YAML-subset
//! parser ([`yaml`]) plus a typed, validated schema ([`schema`]) covering
//! every component (servers, gateway, autoscaler, cluster, monitoring).
//! Per-site presets live in `configs/*.yaml`, mirroring the paper's
//! deployments at Purdue Geddes/Anvil, NRP and UChicago (§3).

pub mod schema;
pub mod yaml;

pub use schema::{
    AutoscalerConfig, BatchMode, CanaryConfig, ClusterConfig, DeploymentConfig,
    EnginesConfig, ExecutionMode, FederationConfig, GatewayConfig, LbPolicy,
    ModelConfig, ModelPlacementConfig, MonitoringConfig, ObservabilityConfig,
    PerModelScalingConfig, PlacementPolicy, PriorityConfig, RpcConfig, ServerConfig,
    ServiceModelConfig, SiteConfig, SloConfig, VersionSpec,
};
pub use yaml::Value;
