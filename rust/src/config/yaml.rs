//! Hand-written parser for the YAML subset used by our config files and
//! model-repository metadata (serde/serde_yaml are unavailable offline).
//!
//! Supported syntax — deliberately the subset Helm values files actually
//! use:
//!
//! * block mappings (`key: value`) nested by indentation,
//! * block sequences (`- item`, including sequences of mappings),
//! * flow sequences (`[1, 2, 3]`) and flow mappings
//!   (`{base: 0.005, per_row: 0.0015}`),
//! * scalars: null/~, true/false, integers, floats, plain and quoted
//!   strings,
//! * `#` comments and blank lines.
//!
//! Not supported (rejected with an error rather than misparsed): anchors,
//! aliases, multi-document streams, block scalars (`|`, `>`), tabs for
//! indentation.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed YAML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Value>),
    /// Key order is preserved (BTreeMap would re-sort; config rendering
    /// and error messages read better in file order).
    Map(Vec<(String, Value)>),
}

/// Parse error with 1-based line number.
#[derive(Debug, thiserror::Error)]
#[error("yaml parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl Value {
    // -- accessors ---------------------------------------------------------

    /// Map lookup by key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup by dotted path (`"gateway.rate_limit.capacity"`).
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// String value (strict — numbers are not coerced).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Float value (integers coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Bool value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Sequence items.
    pub fn as_seq(&self) -> Option<&[Value]> {
        match self {
            Value::Seq(items) => Some(items),
            _ => None,
        }
    }

    /// Map entries in file order.
    pub fn as_map(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Map(entries) => Some(entries),
            _ => None,
        }
    }

    /// True if `Value::Null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Map keys, or empty.
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Value::Map(entries) => entries.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    /// Convert to a string map for flat sections (labels etc.).
    pub fn to_string_map(&self) -> BTreeMap<String, String> {
        let mut out = BTreeMap::new();
        if let Value::Map(entries) = self {
            for (k, v) in entries {
                out.insert(k.clone(), v.render_scalar());
            }
        }
        out
    }

    fn render_scalar(&self) -> String {
        match self {
            Value::Null => "null".into(),
            Value::Bool(b) => b.to_string(),
            Value::Int(v) => v.to_string(),
            Value::Float(v) => v.to_string(),
            Value::Str(s) => s.clone(),
            Value::Seq(_) | Value::Map(_) => format!("{self}"),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn go(v: &Value, indent: usize, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            let pad = "  ".repeat(indent);
            match v {
                Value::Map(entries) => {
                    for (k, val) in entries {
                        match val {
                            Value::Map(_) | Value::Seq(_) if !is_empty(val) => {
                                writeln!(f, "{pad}{k}:")?;
                                go(val, indent + 1, f)?;
                            }
                            _ => writeln!(f, "{pad}{k}: {}", val.render_scalar())?,
                        }
                    }
                    Ok(())
                }
                Value::Seq(items) => {
                    for item in items {
                        match item {
                            Value::Map(_) | Value::Seq(_) => {
                                writeln!(f, "{pad}-")?;
                                go(item, indent + 1, f)?;
                            }
                            _ => writeln!(f, "{pad}- {}", item.render_scalar())?,
                        }
                    }
                    Ok(())
                }
                scalar => writeln!(f, "{pad}{}", scalar.render_scalar()),
            }
        }
        fn is_empty(v: &Value) -> bool {
            matches!(v, Value::Map(m) if m.is_empty())
                || matches!(v, Value::Seq(s) if s.is_empty())
        }
        go(self, 0, f)
    }
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

struct Line<'a> {
    number: usize,
    indent: usize,
    content: &'a str,
}

/// Parse a YAML document into a [`Value`].
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut lines = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let number = i + 1;
        if raw.contains('\t') {
            return Err(err(number, "tabs are not allowed for indentation"));
        }
        let stripped = strip_comment(raw);
        let trimmed = stripped.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        if trimmed.trim() == "---" {
            if !lines.is_empty() {
                return Err(err(number, "multi-document streams are not supported"));
            }
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        lines.push(Line { number, indent, content: trimmed.trim_start() });
    }
    if lines.is_empty() {
        return Ok(Value::Map(Vec::new()));
    }
    let mut pos = 0;
    let root_indent = lines[0].indent;
    let value = parse_block(&lines, &mut pos, root_indent)?;
    if pos != lines.len() {
        return Err(err(
            lines[pos].number,
            "unexpected content (likely inconsistent indentation)",
        ));
    }
    Ok(value)
}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_single = false;
    let mut in_double = false;
    for (i, c) in line.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '#' if !in_single && !in_double => {
                // YAML requires '#' to start a comment at start or after
                // whitespace.
                if i == 0 || line.as_bytes()[i - 1].is_ascii_whitespace() {
                    return &line[..i];
                }
            }
            _ => {}
        }
    }
    line
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let first = &lines[*pos];
    if first.content.starts_with("- ") || first.content == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(err(line.number, "unexpected indentation inside sequence"));
        }
        if !(line.content.starts_with("- ") || line.content == "-") {
            break;
        }
        let number = line.number;
        let rest = line.content[1..].trim_start().to_string();
        *pos += 1;
        if rest.is_empty() {
            // nested block on following lines
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Value::Null);
            }
        } else if rest.contains(": ") || rest.ends_with(':') {
            // Inline start of a mapping item: "- key: value". The item's
            // mapping body sits at the dash indent + 2 ("- " width).
            let item_indent = indent + 2;
            let mut entries = Vec::new();
            parse_map_entry(&rest, number, lines, pos, item_indent, &mut entries)?;
            // Subsequent keys of the same item.
            while *pos < lines.len()
                && lines[*pos].indent == item_indent
                && !(lines[*pos].content.starts_with("- ") || lines[*pos].content == "-")
            {
                let content = lines[*pos].content.to_string();
                let n = lines[*pos].number;
                *pos += 1;
                parse_map_entry(&content, n, lines, pos, item_indent, &mut entries)?;
            }
            items.push(Value::Map(entries));
        } else {
            items.push(parse_scalar(&rest, number)?);
        }
    }
    Ok(Value::Seq(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let mut entries: Vec<(String, Value)> = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(err(line.number, "unexpected indentation inside mapping"));
        }
        if line.content.starts_with("- ") || line.content == "-" {
            break;
        }
        let content = line.content.to_string();
        let number = line.number;
        *pos += 1;
        parse_map_entry(&content, number, lines, pos, indent, &mut entries)?;
    }
    Ok(Value::Map(entries))
}

fn parse_map_entry(
    content: &str,
    number: usize,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    entries: &mut Vec<(String, Value)>,
) -> Result<(), ParseError> {
    let colon = find_key_colon(content)
        .ok_or_else(|| err(number, format!("expected 'key: value', got '{content}'")))?;
    let key = unquote(content[..colon].trim());
    if key.is_empty() {
        return Err(err(number, "empty mapping key"));
    }
    if entries.iter().any(|(k, _)| k == &key) {
        return Err(err(number, format!("duplicate key '{key}'")));
    }
    let rest = content[colon + 1..].trim();
    let value = if rest.is_empty() {
        // nested block (or empty value)
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            parse_block(lines, pos, child_indent)?
        } else {
            Value::Null
        }
    } else {
        parse_scalar(rest, number)?
    };
    entries.push((key, value));
    Ok(())
}

/// Find the colon separating key from value (respecting quoted keys).
fn find_key_colon(s: &str) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                if i + 1 == bytes.len() || bytes[i + 1].is_ascii_whitespace() {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s.starts_with('[') {
        return parse_flow_seq(s, line);
    }
    if s.starts_with('{') {
        return parse_flow_map(s, line);
    }
    if s.starts_with('&') || s.starts_with('*') {
        return Err(err(line, "anchors/aliases are not supported"));
    }
    if s == "|" || s == ">" {
        return Err(err(line, "block scalars are not supported"));
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return Ok(Value::Str(unquote(s)));
    }
    Ok(match s {
        "null" | "~" | "Null" | "NULL" => Value::Null,
        "true" | "True" | "TRUE" => Value::Bool(true),
        "false" | "False" | "FALSE" => Value::Bool(false),
        _ => {
            if let Ok(i) = s.parse::<i64>() {
                Value::Int(i)
            } else if let Ok(f) = s.parse::<f64>() {
                Value::Float(f)
            } else {
                Value::Str(s.to_string())
            }
        }
    })
}

fn parse_flow_seq(s: &str, line: usize) -> Result<Value, ParseError> {
    if !s.ends_with(']') {
        return Err(err(line, "unterminated flow sequence"));
    }
    let inner = &s[1..s.len() - 1];
    let mut items = Vec::new();
    if inner.trim().is_empty() {
        return Ok(Value::Seq(items));
    }
    // split on commas outside quotes/brackets
    let mut depth = 0usize;
    let mut in_single = false;
    let mut in_double = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '[' if !in_single && !in_double => depth += 1,
            ']' if !in_single && !in_double => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| err(line, "unbalanced brackets"))?;
            }
            ',' if depth == 0 && !in_single && !in_double => {
                items.push(parse_scalar(inner[start..i].trim(), line)?);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(parse_scalar(inner[start..].trim(), line)?);
    Ok(Value::Seq(items))
}

/// Split `inner` on top-level commas (outside quotes and `[]`/`{}`).
fn split_flow_items(inner: &str, line: usize) -> Result<Vec<&str>, ParseError> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_single = false;
    let mut in_double = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '[' | '{' if !in_single && !in_double => depth += 1,
            ']' | '}' if !in_single && !in_double => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| err(line, "unbalanced brackets"))?;
            }
            ',' if depth == 0 && !in_single && !in_double => {
                parts.push(inner[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(inner[start..].trim());
    Ok(parts)
}

/// Parse a flow mapping: `{key: value, key2: value2}`.
fn parse_flow_map(s: &str, line: usize) -> Result<Value, ParseError> {
    if !s.ends_with('}') {
        return Err(err(line, "unterminated flow mapping"));
    }
    let inner = &s[1..s.len() - 1];
    let mut entries: Vec<(String, Value)> = Vec::new();
    if inner.trim().is_empty() {
        return Ok(Value::Map(entries));
    }
    for part in split_flow_items(inner, line)? {
        let colon = find_key_colon(part)
            .ok_or_else(|| err(line, format!("expected 'key: value' in flow mapping, got '{part}'")))?;
        let key = unquote(part[..colon].trim());
        if key.is_empty() {
            return Err(err(line, "empty flow-mapping key"));
        }
        if entries.iter().any(|(k, _)| k == &key) {
            return Err(err(line, format!("duplicate key '{key}' in flow mapping")));
        }
        let value = parse_scalar(part[colon + 1..].trim(), line)?;
        entries.push((key, value));
    }
    Ok(Value::Map(entries))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let v = parse("a: 1\nb: 2.5\nc: hello\nd: true\ne: null\nf: \"quoted: str\"\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str(), Some("hello"));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert!(v.get("e").unwrap().is_null());
        assert_eq!(v.get("f").unwrap().as_str(), Some("quoted: str"));
    }

    #[test]
    fn nested_mapping_and_path() {
        let v = parse("outer:\n  inner:\n    leaf: 42\n").unwrap();
        assert_eq!(v.get_path("outer.inner.leaf").unwrap().as_i64(), Some(42));
        assert!(v.get_path("outer.missing").is_none());
    }

    #[test]
    fn subsection_between_scalar_keys() {
        // The autoscaler.per_model shape: a nested map sandwiched between
        // sibling scalars at the parent indent, with comments inside.
        let text = "autoscaler:\n  enabled: true\n  per_model:\n    # dedicated pods\n    \
                    enabled: true\n    threshold: 200\n  max_replicas: 6\n";
        let v = parse(text).unwrap();
        assert_eq!(v.get_path("autoscaler.enabled").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_path("autoscaler.per_model.threshold").unwrap().as_f64(), Some(200.0));
        assert_eq!(v.get_path("autoscaler.max_replicas").unwrap().as_i64(), Some(6));
    }

    #[test]
    fn block_sequence() {
        let v = parse("items:\n  - 1\n  - 2\n  - three\n").unwrap();
        let seq = v.get("items").unwrap().as_seq().unwrap();
        assert_eq!(seq.len(), 3);
        assert_eq!(seq[2].as_str(), Some("three"));
    }

    #[test]
    fn sequence_of_mappings() {
        let text = "models:\n  - name: a\n    batch: 4\n  - name: b\n    batch: 8\n";
        let v = parse(text).unwrap();
        let seq = v.get("models").unwrap().as_seq().unwrap();
        assert_eq!(seq.len(), 2);
        assert_eq!(seq[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(seq[1].get("batch").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn flow_sequence() {
        let v = parse("dims: [1, 2, 3]\nnames: [a, \"b c\"]\nempty: []\n").unwrap();
        assert_eq!(
            v.get("dims").unwrap().as_seq().unwrap().iter()
                .map(|x| x.as_i64().unwrap()).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(v.get("names").unwrap().as_seq().unwrap()[1].as_str(), Some("b c"));
        assert!(v.get("empty").unwrap().as_seq().unwrap().is_empty());
    }

    #[test]
    fn comments_and_blanks() {
        let text = "# header\na: 1  # trailing\n\nb: \"#notcomment\"\n";
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("#notcomment"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn tabs_rejected() {
        assert!(parse("a:\n\tb: 1\n").is_err());
    }

    #[test]
    fn anchors_rejected() {
        assert!(parse("a: &anchor 1\n").is_err());
    }

    #[test]
    fn flow_map_parses() {
        let v = parse("sm: {base: 0.005, per_row: 0.0015}\nempty: {}\n").unwrap();
        assert_eq!(v.get_path("sm.base").unwrap().as_f64(), Some(0.005));
        assert_eq!(v.get_path("sm.per_row").unwrap().as_f64(), Some(0.0015));
        assert!(v.get("empty").unwrap().as_map().unwrap().is_empty());
    }

    #[test]
    fn flow_map_nested_in_flow_seq() {
        let v = parse("xs: [{a: 1}, {a: 2}]\n").unwrap();
        let seq = v.get("xs").unwrap().as_seq().unwrap();
        assert_eq!(seq[1].get("a").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn flow_map_errors() {
        assert!(parse("a: {b: 1\n").is_err()); // unterminated
        assert!(parse("a: {b 1}\n").is_err()); // no colon
        assert!(parse("a: {b: 1, b: 2}\n").is_err()); // duplicate
    }

    #[test]
    fn block_scalar_rejected() {
        assert!(parse("a: |\n  text\n").is_err());
    }

    #[test]
    fn empty_doc_is_empty_map() {
        assert_eq!(parse("").unwrap(), Value::Map(Vec::new()));
        assert_eq!(parse("# only comments\n").unwrap(), Value::Map(Vec::new()));
    }

    #[test]
    fn error_carries_line_number() {
        let e = parse("a: 1\nb: {bad}\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn display_roundtrip() {
        let text = "server:\n  replicas: 3\n  models:\n    - name: pn\n      batch: 4\nflag: true\n";
        let v = parse(text).unwrap();
        let rendered = v.to_string();
        let v2 = parse(&rendered).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn keys_in_file_order() {
        let v = parse("z: 1\na: 2\nm: 3\n").unwrap();
        assert_eq!(v.keys(), vec!["z", "a", "m"]);
    }

    #[test]
    fn colon_in_url_value() {
        let v = parse("url: http://host:9090/metrics\n").unwrap();
        assert_eq!(v.get("url").unwrap().as_str(), Some("http://host:9090/metrics"));
    }
}
