//! `supersonic` — the leader binary.
//!
//! ```text
//!     supersonic serve    --config configs/quickstart.yaml [--duration 60]
//!     supersonic check    --config configs/nrp.yaml
//!     supersonic infer    --addr 127.0.0.1:8001 --model particlenet [--rows 8] [--count 10] [--token t] [--priority critical]
//!     supersonic loadtest --config configs/quickstart.yaml --schedule 1:30,10:60,1:30 [--rows 16] [--priority bulk]
//!     supersonic token    --secret <deployment-secret>
//! ```
//!
//! `serve` is the production entrypoint: boot the full deployment from a
//! config and serve until the duration elapses (0 = forever). The other
//! subcommands are operator tooling: config validation, an ad-hoc client,
//! a perf_analyzer-style load test and auth-token minting.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use supersonic::config::DeploymentConfig;
use supersonic::deployment::Deployment;
use supersonic::gateway::auth;
use supersonic::rpc::client::RpcClient;
use supersonic::rpc::codec::Status;
use supersonic::runtime::Tensor;
use supersonic::workload::{ClientPool, Schedule, WorkloadSpec};

fn main() {
    supersonic::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .with_context(|| format!("expected --flag, got '{}'", args[i]))?;
        let value = args
            .get(i + 1)
            .with_context(|| format!("--{key} needs a value"))?;
        flags.insert(key.to_string(), value.clone());
        i += 2;
    }
    Ok(flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str> {
    flags
        .get(key)
        .map(|s| s.as_str())
        .with_context(|| format!("missing required --{key}"))
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "serve" => cmd_serve(&flags),
        "check" => cmd_check(&flags),
        "infer" => cmd_infer(&flags),
        "loadtest" => cmd_loadtest(&flags),
        "explain" => cmd_explain(&flags),
        "token" => cmd_token(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'supersonic help')"),
    }
}

fn print_usage() {
    println!(
        "supersonic — cloud-native ML inference-as-a-service (SuperSONIC reproduced)\n\n\
         USAGE:\n\
         \x20 supersonic serve    --config <yaml> [--duration <secs>]\n\
         \x20 supersonic check    --config <yaml>\n\
         \x20 supersonic infer    --addr <host:port> --model <name> [--rows N] [--count N] [--token T] [--priority bulk|standard|critical]\n\
         \x20 supersonic loadtest --config <yaml> --schedule C:S,C:S,... [--rows N] [--model NAME] [--priority P]\n\
         \x20 supersonic explain  --config <yaml> [--model M] [--site S] [--since SECS] [--duration SECS] [--fail-site S]\n\
         \x20 supersonic token    --secret <secret>\n"
    );
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = DeploymentConfig::from_file(std::path::Path::new(flag(flags, "config")?))?;
    let duration: f64 = flags
        .get("duration")
        .map(|s| s.parse())
        .transpose()
        .context("--duration must be seconds")?
        .unwrap_or(0.0);

    let replicas = cfg.server.replicas;
    let d = Deployment::up(cfg)?;
    if !d.wait_ready(replicas.min(1), Duration::from_secs(60)) {
        bail!("no instance became ready within 60s");
    }
    println!("deployment '{}' ready", d.cfg.name);
    println!("  inference endpoint: {}", d.endpoint());
    if let Some(m) = d.metrics_endpoint() {
        println!("  metrics endpoint:   http://{m}/metrics");
    }
    println!("  models: {}", d.repository.names().join(", "));
    if duration > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(duration));
        println!("duration elapsed, shutting down");
        d.down();
    } else {
        println!("serving until killed (ctrl-c)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    Ok(())
}

fn cmd_check(flags: &HashMap<String, String>) -> Result<()> {
    let path = std::path::Path::new(flag(flags, "config")?);
    let cfg = DeploymentConfig::from_file(path)?;
    println!("{} OK", path.display());
    println!("  name:        {}", cfg.name);
    println!(
        "  server:      {} replicas, execution={}, {} model(s)",
        cfg.server.replicas,
        cfg.server.execution.name(),
        cfg.server.models.len()
    );
    for m in &cfg.server.models {
        println!(
            "    - {} (queue_delay={:?}, preferred_batch={})",
            m.name, m.max_queue_delay, m.preferred_batch
        );
    }
    println!(
        "  gateway:     lb={}, rate_limit={} rps, auth={}",
        cfg.gateway.lb_policy.name(),
        cfg.gateway.rate_limit_rps,
        if cfg.gateway.auth_secret.is_some() { "on" } else { "off" }
    );
    println!(
        "  autoscaler:  {} (metric={}, threshold={}, replicas {}..{})",
        if cfg.autoscaler.enabled { "on" } else { "off" },
        cfg.autoscaler.metric,
        cfg.autoscaler.threshold,
        cfg.autoscaler.min_replicas,
        cfg.autoscaler.max_replicas
    );
    if cfg.autoscaler.per_model.enabled {
        println!(
            "    per-model: demand threshold {} req/s per replica, {}..{} pods/model \
             (budget {} pods total)",
            cfg.autoscaler.per_model.threshold,
            cfg.autoscaler.per_model.min_replicas,
            cfg.autoscaler.per_model.max_replicas,
            cfg.autoscaler.max_replicas
        );
    }
    println!(
        "  cluster:     {} nodes x {} GPUs (capacity {})",
        cfg.cluster.nodes,
        cfg.cluster.gpus_per_node,
        cfg.cluster.nodes * cfg.cluster.gpus_per_node
    );
    if cfg.engines.cpu_replicas > 0 || cfg.server.models.iter().any(|m| !m.backends.is_empty()) {
        println!(
            "  engines:     default={}, {} cpu pod(s), onnx-sim {}x latency",
            cfg.engines.default_backend,
            cfg.engines.cpu_replicas,
            cfg.engines.onnx_slowdown
        );
        for m in &cfg.server.models {
            if !m.backends.is_empty() {
                println!("    - {} backends: {}", m.name, m.backends.join(" > "));
            }
        }
    }
    if cfg.model_placement.mesh_enabled() {
        println!(
            "  placement:   {} (budget {} MB/instance, thresholds {}/{} req/s, min {} replica(s)/model)",
            cfg.model_placement.policy.name(),
            cfg.model_placement.memory_budget_mb,
            cfg.model_placement.load_threshold,
            cfg.model_placement.unload_threshold,
            cfg.model_placement.min_replicas_per_model
        );
    } else {
        println!("  placement:   off (all models on every instance)");
    }
    println!(
        "  observability: trace sample_rate={}, capacity={} span(s); SLO windows {}s/{}s, \
         burn threshold {}x, eval every {}s",
        cfg.observability.trace_sample_rate,
        cfg.observability.trace_capacity,
        cfg.observability.slo_fast_window.as_secs(),
        cfg.observability.slo_slow_window.as_secs(),
        cfg.observability.slo_burn_threshold,
        cfg.observability.slo_eval_interval.as_secs(),
    );
    if cfg.observability.slos.is_empty() {
        println!("    slos: none configured (burn-rate engine stays off)");
    }
    for s in &cfg.observability.slos {
        println!(
            "    - {}: latency_p99 <= {:?}, error_budget {}",
            s.model, s.latency_p99, s.error_budget
        );
    }
    Ok(())
}

fn cmd_infer(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flag(flags, "addr")?;
    let model = flag(flags, "model")?;
    let rows: usize = flags.get("rows").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let count: usize = flags.get("count").map(|s| s.parse()).transpose()?.unwrap_or(1);

    let mut client = RpcClient::connect(addr)?;
    if let Some(token) = flags.get("token") {
        client = client.with_token(token);
    }
    if let Some(p) = flags.get("priority") {
        client = client.with_priority(supersonic::rpc::codec::Priority::parse(p)?);
    }

    // Input shape from the local repository metadata if present, else
    // --shape d0,d1,...
    let shape: Vec<usize> = match flags.get("shape") {
        Some(s) => s
            .split(',')
            .map(|d| d.parse().context("bad --shape"))
            .collect::<Result<_>>()?,
        None => {
            let repo = supersonic::server::ModelRepository::load_metadata(
                std::path::Path::new("artifacts"),
                &[model.to_string()],
            )
            .context("cannot infer input shape; pass --shape d0,d1,...")?;
            repo.get(model).unwrap().input_shape.clone()
        }
    };
    let mut full_shape = vec![rows];
    full_shape.extend_from_slice(&shape);

    let mut ok = 0;
    let t0 = std::time::Instant::now();
    for i in 0..count {
        let resp = client.infer(model, Tensor::zeros(full_shape.clone()))?;
        if resp.status == Status::Ok {
            ok += 1;
            if i == 0 {
                println!(
                    "output shape {:?}, queue {}us, compute {}us, batched {} rows",
                    resp.output.shape(),
                    resp.queue_us,
                    resp.compute_us,
                    resp.batch_size
                );
            }
        } else {
            println!("request {i}: {} ({})", resp.status.name(), resp.error);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{ok}/{count} ok in {:.3}s ({:.1} req/s, {:.1} rows/s)",
        dt,
        count as f64 / dt,
        (count * rows) as f64 / dt
    );
    Ok(())
}

fn cmd_loadtest(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = DeploymentConfig::from_file(std::path::Path::new(flag(flags, "config")?))?;
    let schedule_spec = flag(flags, "schedule")?;
    let rows: usize = flags.get("rows").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| cfg.server.models[0].name.clone());

    let mut schedule = Schedule::new();
    for part in schedule_spec.split(',') {
        let (clients, secs) = part
            .split_once(':')
            .with_context(|| format!("bad schedule part '{part}' (want clients:secs)"))?;
        schedule = schedule.phase(
            clients.parse().context("bad client count")?,
            Duration::from_secs_f64(secs.parse().context("bad phase seconds")?),
        );
    }

    let replicas = cfg.server.replicas;
    let token = cfg
        .gateway
        .auth_secret
        .as_deref()
        .map(auth::mint_token)
        .unwrap_or_default();
    let d = Deployment::up(cfg)?;
    if !d.wait_ready(replicas.min(1), Duration::from_secs(60)) {
        bail!("deployment did not become ready");
    }
    let input_shape = d.repository.get(&model).context("model not served")?.input_shape.clone();

    let mut spec = WorkloadSpec::new(&model, rows, input_shape);
    spec.token = token;
    if let Some(p) = flags.get("priority") {
        spec.priority = supersonic::rpc::codec::Priority::parse(p)?;
    }
    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    println!(
        "loadtest: model={model} rows/request={rows} schedule={schedule_spec} (clock time)"
    );
    let report = pool.run_with(&schedule, |i, c| {
        println!("-- phase {i}: {c} client(s)");
    });

    println!("\nphase  clients  duration   ok      shed  err   req/s    p50        p99        mean");
    for (i, p) in report.phases.iter().enumerate() {
        println!(
            "{:<6} {:<8} {:<9.1} {:<7} {:<5} {:<5} {:<8.1} {:<10.4} {:<10.4} {:.4}",
            i,
            p.clients,
            p.duration,
            p.ok,
            p.shed,
            p.errors,
            p.throughput(),
            p.latency.quantile(0.5),
            p.latency.quantile(0.99),
            p.latency.mean()
        );
    }
    println!(
        "\noverall: {} ok, {} shed, {} errors, {:.1} req/s, mean latency {:.4}s",
        report.total_ok,
        report.total_shed,
        report.total_errors,
        report.throughput(),
        report.overall_latency.mean()
    );
    d.down();
    Ok(())
}

/// Boot the deployment, drive a short burst of traffic (optionally
/// killing and recovering one site mid-run), then print the flight
/// recorder's causal explain view for the requested scope.
fn cmd_explain(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = DeploymentConfig::from_file(std::path::Path::new(flag(flags, "config")?))?;
    let duration: f64 = flags
        .get("duration")
        .map(|s| s.parse())
        .transpose()
        .context("--duration must be seconds")?
        .unwrap_or(6.0);
    let since_back: Option<f64> = flags
        .get("since")
        .map(|s| s.parse())
        .transpose()
        .context("--since must be seconds (how far back to explain)")?;
    if cfg.observability.flight_recorder_capacity == 0 {
        bail!("flight recorder disabled: set observability.flight_recorder_capacity > 0");
    }

    let token = cfg
        .gateway
        .auth_secret
        .as_deref()
        .map(auth::mint_token)
        .unwrap_or_default();
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| cfg.server.models[0].name.clone());
    let d = Deployment::up(cfg)?;
    if !d.wait_ready(1, Duration::from_secs(60)) {
        bail!("deployment did not become ready");
    }
    let flight = d.flight.clone().expect("capacity > 0 arms the recorder");

    // Drive traffic so the control loops have decisions worth
    // explaining; a --fail-site outage is injected a third of the way
    // in and recovered at two thirds, leaving time for the rebalancer
    // and router to react on both edges.
    let input_shape = d
        .repository
        .get(&d.repository.serving_name(&model))
        .with_context(|| format!("model '{model}' not served"))?
        .input_shape
        .clone();
    let mut full_shape = vec![4];
    full_shape.extend_from_slice(&input_shape);
    let mut client = RpcClient::connect(&d.endpoint())?;
    if !token.is_empty() {
        client = client.with_token(&token);
    }
    let fail_site = flags.get("fail-site").map(|s| s.as_str());
    let t0 = std::time::Instant::now();
    let total = Duration::from_secs_f64(duration);
    let mut failed = false;
    let mut recovered = false;
    while t0.elapsed() < total {
        let _ = client.infer(&model, Tensor::zeros(full_shape.clone()));
        if let (Some(site), Some(f)) = (fail_site, &d.federation) {
            if !failed && t0.elapsed() > total / 3 {
                failed = true;
                if !f.fail_site(site) {
                    bail!("--fail-site '{site}' does not name a configured site");
                }
                println!("# injected outage: site '{site}' down");
            }
            if failed && !recovered && t0.elapsed() > total * 2 / 3 {
                recovered = true;
                f.recover_site(site);
                println!("# injected recovery: site '{site}' back");
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    let filter = supersonic::telemetry::flight::ExplainFilter {
        model: flags.get("model").cloned(),
        site: flags.get("site").cloned(),
        since: since_back.map(|back| d.clock.now_secs() - back),
    };
    print!("{}", flight.explain(&filter));
    d.down();
    Ok(())
}

fn cmd_token(flags: &HashMap<String, String>) -> Result<()> {
    println!("{}", auth::mint_token(flag(flags, "secret")?));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_pairs() {
        let args: Vec<String> =
            ["--config", "a.yaml", "--duration", "5"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args).unwrap();
        assert_eq!(f.get("config").unwrap(), "a.yaml");
        assert_eq!(f.get("duration").unwrap(), "5");
    }

    #[test]
    fn parse_flags_rejects_bare_values() {
        let args: Vec<String> = ["oops"].iter().map(|s| s.to_string()).collect();
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn parse_flags_rejects_missing_value() {
        let args: Vec<String> = ["--config"].iter().map(|s| s.to_string()).collect();
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&["bogus".to_string()]).is_err());
    }
}
