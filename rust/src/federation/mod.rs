//! Multi-site federation — hierarchical placement, autoscaling and
//! routing across clusters (§3's multi-cluster deployments, lifted into
//! one control plane).
//!
//! The paper's production footprint is not one cluster: SuperSONIC runs
//! simultaneously at Purdue (Geddes/Anvil), NRP and UChicago, each site
//! with its own pod budget and accelerator mix, fronted by per-site
//! gateways. This module reproduces that as a *federation*: N
//! [`Site`]s, each a full single-cluster control plane (cluster + mesh
//! router + placement controller + per-model scaler), behind one
//! federation-tier router and one global rebalancer.
//!
//! * [`FederationRouter`] — site-aware routing. Each request goes to
//!   the cheapest site (by WAN penalty from the gateway site) that has
//!   warm capacity for the model; when a site's per-warm-replica queue
//!   depth crosses `federation.spillover_queue_depth` it is demoted
//!   behind unsaturated sites, so traffic *spills over* to remote warm
//!   capacity instead of queueing locally — and repatriates as soon as
//!   the home site drops back under the threshold ([`site_order`] is
//!   the pure, property-tested ordering rule). A site with zero warm
//!   replicas for the model is never picked.
//! * [`Rebalancer`] — the hierarchical budget loop. Site-local
//!   [`PerModelScaler`]s decide *which models* get pods; the rebalancer
//!   decides *how many pods each site may spend*, shifting the global
//!   budget toward the sites whose site-labeled demand signal
//!   (`routed_requests_total{model=...,site=...}`) runs hot. It also
//!   detects whole-site outages (a previously-up site draining to zero
//!   running pods) and raises `slo_alert_active{alert="site_outage"}`.
//! * [`Site::fail`] / [`Site::recover`] — chaos hooks: failing a site
//!   pauses its scaler and drains its targets to zero; recovery re-seeds
//!   every model at its per-model floor so the site has warm capacity to
//!   repatriate onto (without the seed, a recovered site would never
//!   receive traffic, never accrue demand, and never scale back up).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::autoscaler::PerModelScaler;
use crate::config::FederationConfig;
use crate::metrics::registry::{labels, Counter, Gauge, Registry};
use crate::modelmesh::{ModelRouter, PlacementController};
use crate::orchestrator::Cluster;
use crate::rpc::codec::Status;
use crate::server::Instance;
use crate::telemetry::slo::ALERT_GAUGE;
use crate::util::clock::Clock;

/// Every federation-tier metric family, for the docs gate.
pub const FEDERATION_METRICS: &[&str] = &[
    "federation_site_requests_total",
    "federation_spillover_total",
    "federation_site_budget",
    "federation_wan_hops_total",
];

/// `alert=` label value for the whole-site outage alert.
pub const SITE_OUTAGE_ALERT: &str = "site_outage";

/// One site's routing-relevant state, as seen at pick time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteView {
    /// Warm replicas of the model at this site.
    pub warm: usize,
    /// Queued requests per warm replica (0 when `warm == 0`).
    pub queued_per_warm: f64,
    /// WAN penalty from the gateway site, seconds (0 = local).
    pub wan_cost: f64,
}

/// The federation routing rule, pure for property testing: the order in
/// which sites should be tried for one request.
///
/// * Sites with `warm == 0` are **excluded** — a request is never sent
///   to a site without warm capacity for its model.
/// * Unsaturated sites (`queued_per_warm < saturation_depth`) come
///   first, cheapest WAN penalty first — steady state routes local.
/// * Saturated sites follow, again cheapest first — when *every* warm
///   site is saturated the request still lands somewhere warm rather
///   than erroring (spillover degrades latency before availability).
pub fn site_order(views: &[SiteView], saturation_depth: f64) -> Vec<usize> {
    let by_cost = |order: &mut Vec<usize>| {
        order.sort_by(|&a, &b| {
            views[a]
                .wan_cost
                .partial_cmp(&views[b].wan_cost)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
    };
    let mut unsat: Vec<usize> = Vec::new();
    let mut sat: Vec<usize> = Vec::new();
    for (i, v) in views.iter().enumerate() {
        if v.warm == 0 {
            continue;
        }
        if v.queued_per_warm < saturation_depth {
            unsat.push(i);
        } else {
            sat.push(i);
        }
    }
    by_cost(&mut unsat);
    by_cost(&mut sat);
    unsat.extend(sat);
    unsat
}

/// WAN penalty between two sites from the config's per-site `wan` maps.
/// The maps are treated as symmetric: `a -> b` falls back to `b -> a`,
/// and an unlisted pair costs nothing.
pub fn wan_between(cfg: &FederationConfig, a: &str, b: &str) -> Duration {
    if a == b {
        return Duration::ZERO;
    }
    let find = |x: &str, y: &str| {
        cfg.sites
            .iter()
            .find(|s| s.name == x)
            .and_then(|s| s.wan.get(y).copied())
    };
    find(a, b).or_else(|| find(b, a)).unwrap_or(Duration::ZERO)
}

/// A successful federation pick: the replica, the site it lives at, and
/// the WAN penalty the gateway must pay to reach it.
pub struct FedPick {
    pub instance: Arc<Instance>,
    pub site: String,
    pub wan: Duration,
}

struct FedEndpoint {
    name: String,
    router: Arc<ModelRouter>,
    wan: Duration,
    m_requests: Counter,
    m_spillover: Counter,
    m_wan_hops: Counter,
}

/// Site-aware routing tier: wraps the per-site [`ModelRouter`]s behind
/// one pick/resolve surface the gateway consumes.
pub struct FederationRouter {
    sites: Vec<FedEndpoint>,
    /// Index of the gateway's home site — version-routing policy
    /// (pin/canary resolution) is read from this site's router.
    policy: usize,
    spillover_depth: f64,
}

impl FederationRouter {
    /// Router over `(site name, site router)` pairs; WAN penalties are
    /// taken from `cfg` relative to the gateway site.
    pub fn new(
        cfg: &FederationConfig,
        sites: &[(String, Arc<ModelRouter>)],
        registry: &Registry,
    ) -> Arc<Self> {
        let gateway = cfg.gateway_site();
        let endpoints: Vec<FedEndpoint> = sites
            .iter()
            .map(|(name, router)| {
                let l = labels(&[("site", name)]);
                FedEndpoint {
                    name: name.clone(),
                    router: Arc::clone(router),
                    wan: wan_between(cfg, &gateway, name),
                    m_requests: registry.counter("federation_site_requests_total", &l),
                    m_spillover: registry.counter("federation_spillover_total", &l),
                    m_wan_hops: registry.counter("federation_wan_hops_total", &l),
                }
            })
            .collect();
        let policy = endpoints
            .iter()
            .position(|e| e.name == gateway)
            .unwrap_or(0);
        Arc::new(FederationRouter { sites: endpoints, policy, spillover_depth: cfg.spillover_queue_depth })
    }

    /// Version resolution on the policy site's router, with warm counts
    /// summed over every site — a version drained at one site keeps
    /// resolving while it is warm anywhere in the federation.
    pub fn resolve(&self, name: &str) -> String {
        let warm = |m: &str| -> usize { self.sites.iter().map(|s| s.router.replicas(m)).sum() };
        self.sites[self.policy].router.resolve_with(name, &warm)
    }

    /// The policy site's router (canary/pin state of record).
    pub fn policy_router(&self) -> &Arc<ModelRouter> {
        &self.sites[self.policy].router
    }

    /// Current [`SiteView`]s for `model`, in site order.
    pub fn views_for(&self, model: &str) -> Vec<SiteView> {
        self.sites
            .iter()
            .map(|s| {
                let warm = s.router.replicas(model);
                let queued: usize = s
                    .router
                    .endpoints_for(model)
                    .iter()
                    .map(|i| i.queue_depth_for(model))
                    .sum();
                SiteView {
                    warm,
                    queued_per_warm: if warm == 0 { 0.0 } else { queued as f64 / warm as f64 },
                    wan_cost: s.wan.as_secs_f64(),
                }
            })
            .collect()
    }

    /// Pick a replica for `model` (already version-resolved), skipping
    /// the replica named `exclude` on retries. Sites are tried in
    /// [`site_order`]; the first successful site-local pick wins. A pick
    /// that lands anywhere but the cheapest warm site counts as
    /// spillover; one that leaves the gateway site pays (and counts) a
    /// WAN hop.
    pub fn pick_excluding(
        &self,
        model: &str,
        exclude: Option<&str>,
    ) -> Result<FedPick, Status> {
        let views = self.views_for(model);
        let order = site_order(&views, self.spillover_depth);
        if order.is_empty() {
            return Err(if self.sites.iter().any(|s| s.router.serves(model)) {
                Status::Overloaded
            } else {
                Status::ModelNotFound
            });
        }
        let cheapest = views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.warm > 0)
            .min_by(|(_, a), (_, b)| {
                a.wan_cost
                    .partial_cmp(&b.wan_cost)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i);
        for idx in order {
            let s = &self.sites[idx];
            if let Ok(instance) = s.router.pick_excluding(model, exclude) {
                s.m_requests.inc();
                if Some(idx) != cheapest {
                    s.m_spillover.inc();
                }
                if s.wan > Duration::ZERO {
                    s.m_wan_hops.inc();
                }
                return Ok(FedPick { instance, site: s.name.clone(), wan: s.wan });
            }
        }
        Err(Status::Overloaded)
    }

    /// Whether any site has a Ready instance (federation health probe).
    pub fn ready(&self) -> bool {
        self.sites.iter().any(|s| s.router.ready_instances() > 0)
    }

    /// Requests routed to `site` so far (repatriation probe for tests).
    pub fn site_requests(&self, site: &str) -> u64 {
        self.sites
            .iter()
            .find(|s| s.name == site)
            .map(|s| s.m_requests.get())
            .unwrap_or(0)
    }

    /// Total spillover picks so far.
    pub fn spillover_total(&self) -> u64 {
        self.sites.iter().map(|s| s.m_spillover.get()).sum()
    }
}

/// One federated site: a full single-cluster control plane plus the
/// federation bookkeeping (budget slice, outage drain state).
pub struct Site {
    pub name: String,
    pub cluster: Arc<Cluster>,
    pub router: Arc<ModelRouter>,
    pub placement: Arc<PlacementController>,
    pub scaler: Arc<PerModelScaler>,
    /// Configured pod budget (the rebalancer's proportional prior).
    base_budget: usize,
    /// Per-model floor the site re-seeds to on recovery.
    min_per_model: usize,
    models: Vec<String>,
    saved_cpu: AtomicUsize,
    failed: AtomicBool,
}

impl Site {
    /// Wrap one booted site control plane.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        cluster: Arc<Cluster>,
        router: Arc<ModelRouter>,
        placement: Arc<PlacementController>,
        scaler: Arc<PerModelScaler>,
        base_budget: usize,
        min_per_model: usize,
        models: Vec<String>,
    ) -> Arc<Self> {
        Arc::new(Site {
            name,
            cluster,
            router,
            placement,
            scaler,
            base_budget,
            min_per_model,
            models,
            saved_cpu: AtomicUsize::new(0),
            failed: AtomicBool::new(false),
        })
    }

    /// Whether [`Site::fail`] has been called without a matching
    /// [`Site::recover`].
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    /// Minimum pods this site needs while up (per-model floors).
    fn floor(&self) -> usize {
        self.min_per_model.max(1) * self.models.len()
    }

    /// Aggregate demand over the site's catalog at `now`.
    fn demand(&self, now: f64) -> f64 {
        self.models.iter().map(|m| self.placement.demand_for(m, now)).sum()
    }

    /// Chaos hook: take the whole site down. Pauses the site scaler (so
    /// it cannot fight the drain) and drives every pod target — GPU and
    /// CPU — to zero; the cluster's converge loop then kills the pods
    /// and the routers drop the endpoints.
    pub fn fail(&self) {
        if self.failed.swap(true, Ordering::SeqCst) {
            return;
        }
        log::warn!("federation: site '{}' failing", self.name);
        self.scaler.pause();
        for m in &self.models {
            self.cluster.set_desired_for(m, 0);
        }
        self.saved_cpu.store(self.cluster.cpu_desired(), Ordering::SeqCst);
        self.cluster.set_cpu_desired(0);
    }

    /// Chaos hook: bring the site back. Every model is re-seeded at its
    /// per-model floor — the warm capacity repatriation needs — and the
    /// scaler resumes to grow from there as demand returns.
    pub fn recover(&self) {
        if !self.failed.swap(false, Ordering::SeqCst) {
            return;
        }
        log::info!("federation: site '{}' recovering", self.name);
        for m in &self.models {
            self.cluster.set_desired_for(m, self.min_per_model.max(1));
        }
        self.cluster
            .set_cpu_desired(self.saved_cpu.load(Ordering::SeqCst));
        self.scaler.resume();
    }
}

struct SiteHandles {
    budget: Gauge,
    alert: Gauge,
    /// Latch: the outage alert only fires for a site that has been up.
    ever_up: AtomicBool,
}

/// The global budget loop: periodically re-divides the federation-wide
/// pod budget between sites in proportion to their aggregated demand
/// (each up site keeps at least its per-model floors), and flags
/// whole-site outages.
pub struct Rebalancer {
    sites: Vec<Arc<Site>>,
    total_budget: usize,
    interval: Duration,
    clock: Clock,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    per_site: Vec<SiteHandles>,
}

impl Rebalancer {
    /// Start the loop at `cfg.rebalance_interval` of clock time.
    pub fn start(
        cfg: &FederationConfig,
        sites: Vec<Arc<Site>>,
        clock: Clock,
        registry: &Registry,
    ) -> Arc<Self> {
        let per_site = sites
            .iter()
            .map(|s| {
                let l = labels(&[("site", &s.name)]);
                let alert_l = labels(&[("alert", SITE_OUTAGE_ALERT), ("site", &s.name)]);
                let h = SiteHandles {
                    budget: registry.gauge("federation_site_budget", &l),
                    alert: registry.gauge(ALERT_GAUGE, &alert_l),
                    ever_up: AtomicBool::new(false),
                };
                h.budget.set(s.base_budget as f64);
                h.alert.set(0.0);
                h
            })
            .collect();
        let rb = Arc::new(Rebalancer {
            total_budget: cfg.total_budget(),
            interval: cfg.rebalance_interval,
            sites,
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            per_site,
        });
        let r = Arc::clone(&rb);
        let handle = std::thread::Builder::new()
            .name("fed-rebalancer".into())
            .spawn(move || {
                while !r.stop.load(Ordering::SeqCst) {
                    r.tick();
                    r.clock.sleep(r.interval);
                }
            })
            .expect("spawning federation rebalancer");
        *rb.handle.lock().unwrap() = Some(handle);
        rb
    }

    /// One rebalance pass (used by the loop and by tests).
    pub fn tick(&self) {
        let now = self.clock.now_secs();
        let n = self.sites.len();
        let mut up = vec![false; n];
        let mut demand = vec![0.0; n];
        for (i, s) in self.sites.iter().enumerate() {
            let running = s.cluster.running();
            let h = &self.per_site[i];
            if running > 0 {
                h.ever_up.store(true, Ordering::SeqCst);
            }
            let outage = h.ever_up.load(Ordering::SeqCst) && running == 0;
            if outage && h.alert.get() == 0.0 {
                log::warn!("federation: site '{}' outage detected", s.name);
            }
            h.alert.set(if outage { 1.0 } else { 0.0 });
            up[i] = running > 0 && !s.is_failed();
            demand[i] = if up[i] { s.demand(now) } else { 0.0 };
        }

        // Floors first: every up site keeps room for its per-model
        // minima. The spare budget is split in proportion to demand
        // (largest-remainder rounding); with no demand anywhere, the
        // configured base budgets serve as the prior.
        let floors: Vec<usize> = self
            .sites
            .iter()
            .zip(&up)
            .map(|(s, u)| if *u { s.floor() } else { 0 })
            .collect();
        let floor_sum: usize = floors.iter().sum();
        let spare = self.total_budget.saturating_sub(floor_sum);
        let weights: Vec<f64> = if demand.iter().any(|d| *d > 0.0) {
            demand.clone()
        } else {
            self.sites
                .iter()
                .zip(&up)
                .map(|(s, u)| if *u { s.base_budget as f64 } else { 0.0 })
                .collect()
        };
        let wsum: f64 = weights.iter().sum();
        let mut assigned = floors.clone();
        if wsum > 0.0 && spare > 0 {
            let exact: Vec<f64> = weights.iter().map(|w| spare as f64 * w / wsum).collect();
            let mut rounded: Vec<usize> = exact.iter().map(|e| e.floor() as usize).collect();
            let mut left = spare.saturating_sub(rounded.iter().sum());
            let mut frac: Vec<(usize, f64)> = exact
                .iter()
                .enumerate()
                .map(|(i, e)| (i, e - e.floor()))
                .collect();
            frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            for (i, _) in frac {
                if left == 0 {
                    break;
                }
                if up[i] {
                    rounded[i] += 1;
                    left -= 1;
                }
            }
            for i in 0..n {
                assigned[i] += rounded[i];
            }
        }
        for (i, s) in self.sites.iter().enumerate() {
            if up[i] {
                s.scaler.set_budget(assigned[i]);
            }
            self.per_site[i].budget.set(assigned[i] as f64);
        }
    }

    /// Stop the loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The whole federation: sites, the routing tier and the budget loop.
pub struct Federation {
    pub sites: Vec<Arc<Site>>,
    pub router: Arc<FederationRouter>,
    pub rebalancer: Arc<Rebalancer>,
}

impl Federation {
    /// Look a site up by name.
    pub fn site(&self, name: &str) -> Option<&Arc<Site>> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Chaos hook: kill the named site (see [`Site::fail`]). Returns
    /// false for an unknown name.
    pub fn fail_site(&self, name: &str) -> bool {
        match self.site(name) {
            Some(s) => {
                s.fail();
                true
            }
            None => false,
        }
    }

    /// Chaos hook: recover the named site (see [`Site::recover`]).
    pub fn recover_site(&self, name: &str) -> bool {
        match self.site(name) {
            Some(s) => {
                s.recover();
                true
            }
            None => false,
        }
    }

    /// Running pods across every site.
    pub fn running(&self) -> usize {
        self.sites.iter().map(|s| s.cluster.running()).sum()
    }

    /// Desired pods across every site.
    pub fn desired(&self) -> usize {
        self.sites.iter().map(|s| s.cluster.desired()).sum()
    }

    /// Per-site running pod counts (diagnostics).
    pub fn running_by_site(&self) -> BTreeMap<String, usize> {
        self.sites
            .iter()
            .map(|s| (s.name.clone(), s.cluster.running()))
            .collect()
    }

    /// Tear the federation down: the budget loop first (so it cannot
    /// fight the drain), then every site's scaler and cluster.
    pub fn shutdown(&self) {
        self.rebalancer.shutdown();
        for s in &self.sites {
            s.scaler.shutdown();
            s.cluster.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(warm: usize, q: f64, wan: f64) -> SiteView {
        SiteView { warm, queued_per_warm: q, wan_cost: wan }
    }

    #[test]
    fn order_prefers_cheapest_unsaturated() {
        let views = [v(2, 0.0, 0.03), v(2, 0.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0, 2]);
    }

    #[test]
    fn order_excludes_cold_sites() {
        let views = [v(0, 0.0, 0.0), v(1, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1]);
        assert!(site_order(&[v(0, 0.0, 0.0)], 8.0).is_empty());
    }

    #[test]
    fn saturated_home_spills_to_remote() {
        // Home site (wan 0) saturated, remote warm and idle: remote first.
        let views = [v(2, 10.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0]);
        // Home recovers under the threshold: traffic repatriates.
        let views = [v(2, 3.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![0, 1]);
    }

    #[test]
    fn all_saturated_still_ordered_by_cost() {
        let views = [v(1, 20.0, 0.05), v(1, 30.0, 0.0)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0]);
    }

    #[test]
    fn property_order_never_contains_cold_site() {
        use crate::util::quick::{check, Gen};
        check("site_order excludes warm==0", 300, |g: &mut Gen| {
            let n = g.usize(1..=6);
            let views: Vec<SiteView> = (0..n)
                .map(|_| v(g.usize(0..=3), g.f64(0.0, 20.0), g.f64(0.0, 0.2)))
                .collect();
            let depth = g.f64(0.1, 15.0);
            let order = site_order(&views, depth);
            for &i in &order {
                assert!(views[i].warm > 0, "cold site {i} in order {order:?}");
            }
            // Completeness: every warm site appears exactly once.
            let warm = views.iter().filter(|v| v.warm > 0).count();
            assert_eq!(order.len(), warm);
            let mut seen = order.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), order.len());
        });
    }

    #[test]
    fn property_unsaturated_precede_saturated() {
        use crate::util::quick::{check, Gen};
        check("unsaturated sites sort first", 300, |g: &mut Gen| {
            let n = g.usize(2..=6);
            let views: Vec<SiteView> = (0..n)
                .map(|_| v(g.usize(0..=3), g.f64(0.0, 20.0), g.f64(0.0, 0.2)))
                .collect();
            let depth = g.f64(0.1, 15.0);
            let order = site_order(&views, depth);
            let mut seen_saturated = false;
            for &i in &order {
                let sat = views[i].queued_per_warm >= depth;
                assert!(
                    !(seen_saturated && !sat),
                    "unsaturated site after saturated one: {order:?}"
                );
                seen_saturated |= sat;
            }
        });
    }

    #[test]
    fn wan_lookup_is_symmetric_with_fallback() {
        use crate::config::SiteConfig;
        let mut a = SiteConfig { name: "a".into(), ..SiteConfig::default() };
        a.wan.insert("b".into(), Duration::from_millis(30));
        let b = SiteConfig { name: "b".into(), ..SiteConfig::default() };
        let cfg = FederationConfig { sites: vec![a, b], ..FederationConfig::default() };
        assert_eq!(wan_between(&cfg, "a", "b"), Duration::from_millis(30));
        assert_eq!(wan_between(&cfg, "b", "a"), Duration::from_millis(30));
        assert_eq!(wan_between(&cfg, "a", "a"), Duration::ZERO);
        assert_eq!(wan_between(&cfg, "a", "zz"), Duration::ZERO);
    }
}
