//! Multi-site federation — hierarchical placement, autoscaling and
//! routing across clusters (§3's multi-cluster deployments, lifted into
//! one control plane).
//!
//! The paper's production footprint is not one cluster: SuperSONIC runs
//! simultaneously at Purdue (Geddes/Anvil), NRP and UChicago, each site
//! with its own pod budget and accelerator mix, fronted by per-site
//! gateways. This module reproduces that as a *federation*: N
//! [`Site`]s, each a full single-cluster control plane (cluster + mesh
//! router + placement controller + per-model scaler), behind one
//! federation-tier router and one global rebalancer.
//!
//! * [`FederationRouter`] — site-aware routing. Each request goes to
//!   the cheapest site (by WAN penalty from the gateway site) that has
//!   warm capacity for the model; when a site's per-warm-replica queue
//!   depth crosses its *derived knee* — the configured
//!   `federation.spillover_queue_depth` scaled by the site's share of
//!   the rebalancer's current budget split ([`derived_depths`]), so the
//!   router and rebalancer cannot disagree mid-budget-shift — it is
//!   demoted behind unsaturated sites, so traffic *spills over* to
//!   remote warm capacity instead of queueing locally — and repatriates
//!   as soon as the home site drops back under its knee ([`site_order`]
//!   is the pure, property-tested ordering rule). A site with zero warm
//!   replicas for the model is never picked. Spillover onsets, home-site
//!   failovers and repatriations land in the control-plane flight
//!   recorder with the derived knee they were decided from.
//! * [`Rebalancer`] — the hierarchical budget loop. Site-local
//!   [`PerModelScaler`]s decide *which models* get pods; the rebalancer
//!   decides *how many pods each site may spend*, shifting the global
//!   budget toward the sites whose site-labeled demand signal
//!   (`routed_requests_total{model=...,site=...}`) runs hot. It also
//!   detects whole-site outages (a previously-up site draining to zero
//!   running pods) and raises `slo_alert_active{alert="site_outage"}`.
//! * [`Site::fail`] / [`Site::recover`] — chaos hooks: failing a site
//!   pauses its scaler and drains its targets to zero; recovery re-seeds
//!   every model at its per-model floor so the site has warm capacity to
//!   repatriate onto (without the seed, a recovered site would never
//!   receive traffic, never accrue demand, and never scale back up).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::autoscaler::PerModelScaler;
use crate::config::FederationConfig;
use crate::metrics::registry::{labels, Counter, Gauge, Registry};
use crate::modelmesh::{ModelRouter, PlacementController};
use crate::orchestrator::Cluster;
use crate::rpc::codec::Status;
use crate::server::Instance;
use crate::telemetry::flight::{DecisionEvent, LoopTicker, RecorderHandle};
use crate::telemetry::slo::ALERT_GAUGE;
use crate::util::clock::Clock;

/// Every federation-tier metric family, for the docs gate.
pub const FEDERATION_METRICS: &[&str] = &[
    "federation_site_requests_total",
    "federation_spillover_total",
    "federation_site_budget",
    "federation_wan_hops_total",
];

/// `alert=` label value for the whole-site outage alert.
pub const SITE_OUTAGE_ALERT: &str = "site_outage";

/// One site's routing-relevant state, as seen at pick time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteView {
    /// Warm replicas of the model at this site.
    pub warm: usize,
    /// Queued requests per warm replica (0 when `warm == 0`).
    pub queued_per_warm: f64,
    /// WAN penalty from the gateway site, seconds (0 = local).
    pub wan_cost: f64,
}

/// The federation routing rule, pure for property testing: the order in
/// which sites should be tried for one request.
///
/// * Sites with `warm == 0` are **excluded** — a request is never sent
///   to a site without warm capacity for its model.
/// * Unsaturated sites (`queued_per_warm < saturation_depth`) come
///   first, cheapest WAN penalty first — steady state routes local.
/// * Saturated sites follow, again cheapest first — when *every* warm
///   site is saturated the request still lands somewhere warm rather
///   than erroring (spillover degrades latency before availability).
pub fn site_order(views: &[SiteView], saturation_depth: f64) -> Vec<usize> {
    site_order_with_depths(views, &vec![saturation_depth; views.len()])
}

/// [`site_order`] with a per-site saturation knee: `depths[i]` is the
/// queue depth at which site `i` is demoted. This is the form the
/// federation router actually runs — knees come from [`derived_depths`]
/// over the rebalancer's live budget split. A missing depth (shorter
/// slice) never demotes that site.
pub fn site_order_with_depths(views: &[SiteView], depths: &[f64]) -> Vec<usize> {
    let by_cost = |order: &mut Vec<usize>| {
        order.sort_by(|&a, &b| {
            views[a]
                .wan_cost
                .partial_cmp(&views[b].wan_cost)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
    };
    let mut unsat: Vec<usize> = Vec::new();
    let mut sat: Vec<usize> = Vec::new();
    for (i, v) in views.iter().enumerate() {
        if v.warm == 0 {
            continue;
        }
        if v.queued_per_warm < depths.get(i).copied().unwrap_or(f64::MAX) {
            unsat.push(i);
        } else {
            sat.push(i);
        }
    }
    by_cost(&mut unsat);
    by_cost(&mut sat);
    unsat.extend(sat);
    unsat
}

/// Per-site spillover knees derived from the rebalancer's current budget
/// split: a site holding `share` of the federation budget saturates at
/// `base_depth * share * nsites`, clamped to ≥ 1.0. Equal budgets reduce
/// to the static `base_depth` (backwards compatible); a budget-starved
/// site is demoted earlier; a budget-rich site absorbs more queueing
/// before spilling. With no budget signal at all (sum ≤ 0) the static
/// depth applies everywhere.
pub fn derived_depths(base_depth: f64, budgets: &[f64]) -> Vec<f64> {
    let n = budgets.len();
    let total: f64 = budgets.iter().map(|b| b.max(0.0)).sum();
    if total <= 0.0 {
        return vec![base_depth; n];
    }
    budgets
        .iter()
        .map(|b| (base_depth * b.max(0.0) * n as f64 / total).max(1.0))
        .collect()
}

/// WAN penalty between two sites from the config's per-site `wan` maps.
/// The maps are treated as symmetric: `a -> b` falls back to `b -> a`,
/// and an unlisted pair costs nothing.
pub fn wan_between(cfg: &FederationConfig, a: &str, b: &str) -> Duration {
    if a == b {
        return Duration::ZERO;
    }
    let find = |x: &str, y: &str| {
        cfg.sites
            .iter()
            .find(|s| s.name == x)
            .and_then(|s| s.wan.get(y).copied())
    };
    find(a, b).or_else(|| find(b, a)).unwrap_or(Duration::ZERO)
}

/// A successful federation pick: the replica, the site it lives at, and
/// the WAN penalty the gateway must pay to reach it.
pub struct FedPick {
    pub instance: Arc<Instance>,
    pub site: String,
    pub wan: Duration,
}

struct FedEndpoint {
    name: String,
    router: Arc<ModelRouter>,
    wan: Duration,
    m_requests: Counter,
    m_spillover: Counter,
    m_wan_hops: Counter,
    /// The site's live pod budget — the *same* registry gauge the
    /// rebalancer writes (`federation_site_budget{site=...}`), read back
    /// at pick time to derive the spillover knee.
    budget: Gauge,
}

/// `away_cause` states for the router's episode tracking.
const AWAY_NONE: usize = 0;
const AWAY_SPILLOVER: usize = 1;
const AWAY_FAILOVER: usize = 2;

/// Site-aware routing tier: wraps the per-site [`ModelRouter`]s behind
/// one pick/resolve surface the gateway consumes.
pub struct FederationRouter {
    sites: Vec<FedEndpoint>,
    /// Index of the gateway's home site — version-routing policy
    /// (pin/canary resolution) is read from this site's router.
    policy: usize,
    spillover_depth: f64,
    recorder: RecorderHandle,
    /// Why traffic is currently landing away from the home site
    /// (`AWAY_*`): decision events fire on transitions, not per pick.
    away_cause: AtomicUsize,
    /// Home-site knee (milli-units) the current away episode was decided
    /// from; a rebalancer budget shift moves it and re-fires the event
    /// with the fresh knee.
    away_knee: AtomicUsize,
}

impl FederationRouter {
    /// Router over `(site name, site router)` pairs; WAN penalties are
    /// taken from `cfg` relative to the gateway site.
    pub fn new(
        cfg: &FederationConfig,
        sites: &[(String, Arc<ModelRouter>)],
        registry: &Registry,
    ) -> Arc<Self> {
        let gateway = cfg.gateway_site();
        let endpoints: Vec<FedEndpoint> = sites
            .iter()
            .map(|(name, router)| {
                let l = labels(&[("site", name)]);
                let budget = registry.gauge("federation_site_budget", &l);
                // Seed with the configured budget so knees are sane
                // before the rebalancer's first tick overwrites this
                // (same gauge handle — the registry deduplicates).
                if let Some(sc) = cfg.sites.iter().find(|s| &s.name == name) {
                    budget.set(sc.pod_budget as f64);
                }
                FedEndpoint {
                    name: name.clone(),
                    router: Arc::clone(router),
                    wan: wan_between(cfg, &gateway, name),
                    m_requests: registry.counter("federation_site_requests_total", &l),
                    m_spillover: registry.counter("federation_spillover_total", &l),
                    m_wan_hops: registry.counter("federation_wan_hops_total", &l),
                    budget,
                }
            })
            .collect();
        let policy = endpoints
            .iter()
            .position(|e| e.name == gateway)
            .unwrap_or(0);
        Arc::new(FederationRouter {
            sites: endpoints,
            policy,
            spillover_depth: cfg.spillover_queue_depth,
            recorder: RecorderHandle::default(),
            away_cause: AtomicUsize::new(AWAY_NONE),
            away_knee: AtomicUsize::new(usize::MAX),
        })
    }

    /// The flight-recorder slot routing decisions land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// Current per-site spillover knees, derived from the rebalancer's
    /// live budget split (in site order — diagnostics and benches).
    pub fn current_depths(&self) -> Vec<f64> {
        let budgets: Vec<f64> = self.sites.iter().map(|s| s.budget.get()).collect();
        derived_depths(self.spillover_depth, &budgets)
    }

    /// Version resolution on the policy site's router, with warm counts
    /// summed over every site — a version drained at one site keeps
    /// resolving while it is warm anywhere in the federation.
    pub fn resolve(&self, name: &str) -> String {
        let warm = |m: &str| -> usize { self.sites.iter().map(|s| s.router.replicas(m)).sum() };
        self.sites[self.policy].router.resolve_with(name, &warm)
    }

    /// The policy site's router (canary/pin state of record).
    pub fn policy_router(&self) -> &Arc<ModelRouter> {
        &self.sites[self.policy].router
    }

    /// Current [`SiteView`]s for `model`, in site order.
    pub fn views_for(&self, model: &str) -> Vec<SiteView> {
        self.sites
            .iter()
            .map(|s| {
                let warm = s.router.replicas(model);
                let queued: usize = s
                    .router
                    .endpoints_for(model)
                    .iter()
                    .map(|i| i.queue_depth_for(model))
                    .sum();
                SiteView {
                    warm,
                    queued_per_warm: if warm == 0 { 0.0 } else { queued as f64 / warm as f64 },
                    wan_cost: s.wan.as_secs_f64(),
                }
            })
            .collect()
    }

    /// Pick a replica for `model` (already version-resolved), skipping
    /// the replica named `exclude` on retries. Sites are tried in
    /// [`site_order_with_depths`] under budget-derived knees; the first
    /// successful site-local pick wins. A pick that lands anywhere but
    /// the cheapest warm site counts as spillover; one that leaves the
    /// gateway site pays (and counts) a WAN hop.
    pub fn pick_excluding(
        &self,
        model: &str,
        exclude: Option<&str>,
    ) -> Result<FedPick, Status> {
        let views = self.views_for(model);
        let depths = self.current_depths();
        let order = site_order_with_depths(&views, &depths);
        if order.is_empty() {
            return Err(if self.sites.iter().any(|s| s.router.serves(model)) {
                Status::Overloaded
            } else {
                Status::ModelNotFound
            });
        }
        let cheapest = views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.warm > 0)
            .min_by(|(_, a), (_, b)| {
                a.wan_cost
                    .partial_cmp(&b.wan_cost)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i);
        for idx in order {
            let s = &self.sites[idx];
            if let Ok(instance) = s.router.pick_excluding(model, exclude) {
                s.m_requests.inc();
                if Some(idx) != cheapest {
                    s.m_spillover.inc();
                }
                if s.wan > Duration::ZERO {
                    s.m_wan_hops.inc();
                }
                self.note_pick(model, idx, &views, &depths);
                return Ok(FedPick { instance, site: s.name.clone(), wan: s.wan });
            }
        }
        Err(Status::Overloaded)
    }

    /// Flight-recorder bookkeeping for one successful pick. Events fire
    /// on *episode transitions*, not per pick: the first pick routed
    /// away from the home site records a `spillover` (home warm but over
    /// its knee) or `failover` (home cold) onset; a changed cause or a
    /// materially-moved home knee (the rebalancer shifted budget under
    /// the episode) re-fires with the fresh inputs; the first pick back
    /// on the home site records `repatriation` and re-arms.
    fn note_pick(&self, model: &str, idx: usize, views: &[SiteView], depths: &[f64]) {
        let home = self.policy;
        let knee = depths.get(home).copied().unwrap_or(self.spillover_depth);
        if idx == home {
            if self.away_cause.swap(AWAY_NONE, Ordering::SeqCst) != AWAY_NONE {
                self.away_knee.store(usize::MAX, Ordering::SeqCst);
                self.recorder.record(
                    DecisionEvent::new("federation_router", "repatriation")
                        .model(model)
                        .site(&self.sites[home].name)
                        .input("derived_knee", knee)
                        .input("home_queued_per_warm", views[home].queued_per_warm)
                        .action(format!(
                            "traffic back on home site '{}'",
                            self.sites[home].name
                        )),
                );
            }
            return;
        }
        let home_view = &views[home];
        if home_view.warm > 0 && home_view.queued_per_warm < knee {
            // Home was pickable but its local pick failed transiently —
            // not an away episode, leave the latch alone.
            return;
        }
        let cause = if home_view.warm == 0 { AWAY_FAILOVER } else { AWAY_SPILLOVER };
        // Knee quantized to milli-units: float jitter must not re-fire.
        let knee_q = (knee * 1000.0).round() as usize;
        let prev_cause = self.away_cause.swap(cause, Ordering::SeqCst);
        let prev_knee = self.away_knee.swap(knee_q, Ordering::SeqCst);
        if prev_cause == cause && prev_knee == knee_q {
            return;
        }
        let (kind, why) = if cause == AWAY_FAILOVER {
            ("failover", "home site has no warm capacity")
        } else {
            ("spillover", "home site over its derived knee")
        };
        self.recorder.record(
            DecisionEvent::new("federation_router", kind)
                .model(model)
                .site(&self.sites[idx].name)
                .input("derived_knee", knee)
                .input("home_queued_per_warm", home_view.queued_per_warm)
                .input("home_warm", home_view.warm as f64)
                .action(format!("routed to '{}' ({why})", self.sites[idx].name))
                .alternative(self.sites[home].name.clone(), home_view.queued_per_warm),
        );
    }

    /// Whether any site has a Ready instance (federation health probe).
    pub fn ready(&self) -> bool {
        self.sites.iter().any(|s| s.router.ready_instances() > 0)
    }

    /// Requests routed to `site` so far (repatriation probe for tests).
    pub fn site_requests(&self, site: &str) -> u64 {
        self.sites
            .iter()
            .find(|s| s.name == site)
            .map(|s| s.m_requests.get())
            .unwrap_or(0)
    }

    /// Total spillover picks so far.
    pub fn spillover_total(&self) -> u64 {
        self.sites.iter().map(|s| s.m_spillover.get()).sum()
    }
}

/// One federated site: a full single-cluster control plane plus the
/// federation bookkeeping (budget slice, outage drain state).
pub struct Site {
    pub name: String,
    pub cluster: Arc<Cluster>,
    pub router: Arc<ModelRouter>,
    pub placement: Arc<PlacementController>,
    pub scaler: Arc<PerModelScaler>,
    /// Configured pod budget (the rebalancer's proportional prior).
    base_budget: usize,
    /// Per-model floor the site re-seeds to on recovery.
    min_per_model: usize,
    models: Vec<String>,
    saved_cpu: AtomicUsize,
    failed: AtomicBool,
}

impl Site {
    /// Wrap one booted site control plane.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        cluster: Arc<Cluster>,
        router: Arc<ModelRouter>,
        placement: Arc<PlacementController>,
        scaler: Arc<PerModelScaler>,
        base_budget: usize,
        min_per_model: usize,
        models: Vec<String>,
    ) -> Arc<Self> {
        Arc::new(Site {
            name,
            cluster,
            router,
            placement,
            scaler,
            base_budget,
            min_per_model,
            models,
            saved_cpu: AtomicUsize::new(0),
            failed: AtomicBool::new(false),
        })
    }

    /// Whether [`Site::fail`] has been called without a matching
    /// [`Site::recover`].
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    /// Minimum pods this site needs while up (per-model floors).
    fn floor(&self) -> usize {
        self.min_per_model.max(1) * self.models.len()
    }

    /// Aggregate demand over the site's catalog at `now`.
    fn demand(&self, now: f64) -> f64 {
        self.models.iter().map(|m| self.placement.demand_for(m, now)).sum()
    }

    /// Chaos hook: take the whole site down. Pauses the site scaler (so
    /// it cannot fight the drain) and drives every pod target — GPU and
    /// CPU — to zero; the cluster's converge loop then kills the pods
    /// and the routers drop the endpoints.
    pub fn fail(&self) {
        if self.failed.swap(true, Ordering::SeqCst) {
            return;
        }
        log::warn!("federation: site '{}' failing", self.name);
        self.scaler.pause();
        for m in &self.models {
            self.cluster.set_desired_for(m, 0);
        }
        self.saved_cpu.store(self.cluster.cpu_desired(), Ordering::SeqCst);
        self.cluster.set_cpu_desired(0);
    }

    /// Chaos hook: bring the site back. Every model is re-seeded at its
    /// per-model floor — the warm capacity repatriation needs — and the
    /// scaler resumes to grow from there as demand returns.
    pub fn recover(&self) {
        if !self.failed.swap(false, Ordering::SeqCst) {
            return;
        }
        log::info!("federation: site '{}' recovering", self.name);
        for m in &self.models {
            self.cluster.set_desired_for(m, self.min_per_model.max(1));
        }
        self.cluster
            .set_cpu_desired(self.saved_cpu.load(Ordering::SeqCst));
        self.scaler.resume();
    }
}

struct SiteHandles {
    budget: Gauge,
    alert: Gauge,
    /// Latch: the outage alert only fires for a site that has been up.
    ever_up: AtomicBool,
}

/// The global budget loop: periodically re-divides the federation-wide
/// pod budget between sites in proportion to their aggregated demand
/// (each up site keeps at least its per-model floors), and flags
/// whole-site outages.
pub struct Rebalancer {
    sites: Vec<Arc<Site>>,
    total_budget: usize,
    interval: Duration,
    clock: Clock,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    per_site: Vec<SiteHandles>,
    recorder: RecorderHandle,
    ticker: LoopTicker,
}

impl Rebalancer {
    /// Start the loop at `cfg.rebalance_interval` of clock time.
    pub fn start(
        cfg: &FederationConfig,
        sites: Vec<Arc<Site>>,
        clock: Clock,
        registry: &Registry,
    ) -> Arc<Self> {
        let per_site = sites
            .iter()
            .map(|s| {
                let l = labels(&[("site", &s.name)]);
                let alert_l = labels(&[("alert", SITE_OUTAGE_ALERT), ("site", &s.name)]);
                let h = SiteHandles {
                    budget: registry.gauge("federation_site_budget", &l),
                    alert: registry.gauge(ALERT_GAUGE, &alert_l),
                    ever_up: AtomicBool::new(false),
                };
                h.budget.set(s.base_budget as f64);
                h.alert.set(0.0);
                h
            })
            .collect();
        let rb = Arc::new(Rebalancer {
            total_budget: cfg.total_budget(),
            interval: cfg.rebalance_interval,
            sites,
            clock: clock.clone(),
            stop: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            per_site,
            recorder: RecorderHandle::default(),
            ticker: LoopTicker::new(registry, clock, "rebalancer"),
        });
        let r = Arc::clone(&rb);
        let handle = std::thread::Builder::new()
            .name("fed-rebalancer".into())
            .spawn(move || {
                while !r.stop.load(Ordering::SeqCst) {
                    r.ticker.tick(|| r.tick());
                    r.clock.sleep(r.interval);
                }
            })
            .expect("spawning federation rebalancer");
        *rb.handle.lock().unwrap() = Some(handle);
        rb
    }

    /// The flight-recorder slot budget decisions land in (installed by
    /// the deployment once the recorder exists).
    pub fn recorder(&self) -> &RecorderHandle {
        &self.recorder
    }

    /// One rebalance pass (used by the loop and by tests).
    pub fn tick(&self) {
        let now = self.clock.now_secs();
        let n = self.sites.len();
        let mut up = vec![false; n];
        let mut demand = vec![0.0; n];
        for (i, s) in self.sites.iter().enumerate() {
            let running = s.cluster.running();
            let h = &self.per_site[i];
            if running > 0 {
                h.ever_up.store(true, Ordering::SeqCst);
            }
            let outage = h.ever_up.load(Ordering::SeqCst) && running == 0;
            if outage && h.alert.get() == 0.0 {
                log::warn!("federation: site '{}' outage detected", s.name);
                self.recorder.record(
                    DecisionEvent::new("rebalancer", "site_outage")
                        .site(&s.name)
                        .input("running", running as f64)
                        .action(format!("latched site_outage alert for '{}'", s.name)),
                );
            }
            if !outage && h.alert.get() == 1.0 {
                self.recorder.record(
                    DecisionEvent::new("rebalancer", "site_recovered")
                        .site(&s.name)
                        .input("running", running as f64)
                        .action(format!("cleared site_outage alert for '{}'", s.name)),
                );
            }
            h.alert.set(if outage { 1.0 } else { 0.0 });
            up[i] = running > 0 && !s.is_failed();
            demand[i] = if up[i] { s.demand(now) } else { 0.0 };
        }

        // Floors first: every up site keeps room for its per-model
        // minima. The spare budget is split in proportion to demand
        // (largest-remainder rounding); with no demand anywhere, the
        // configured base budgets serve as the prior.
        let floors: Vec<usize> = self
            .sites
            .iter()
            .zip(&up)
            .map(|(s, u)| if *u { s.floor() } else { 0 })
            .collect();
        let floor_sum: usize = floors.iter().sum();
        let spare = self.total_budget.saturating_sub(floor_sum);
        let weights: Vec<f64> = if demand.iter().any(|d| *d > 0.0) {
            demand.clone()
        } else {
            self.sites
                .iter()
                .zip(&up)
                .map(|(s, u)| if *u { s.base_budget as f64 } else { 0.0 })
                .collect()
        };
        let wsum: f64 = weights.iter().sum();
        let mut assigned = floors.clone();
        if wsum > 0.0 && spare > 0 {
            let exact: Vec<f64> = weights.iter().map(|w| spare as f64 * w / wsum).collect();
            let mut rounded: Vec<usize> = exact.iter().map(|e| e.floor() as usize).collect();
            let mut left = spare.saturating_sub(rounded.iter().sum());
            let mut frac: Vec<(usize, f64)> = exact
                .iter()
                .enumerate()
                .map(|(i, e)| (i, e - e.floor()))
                .collect();
            frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            for (i, _) in frac {
                if left == 0 {
                    break;
                }
                if up[i] {
                    rounded[i] += 1;
                    left -= 1;
                }
            }
            for i in 0..n {
                assigned[i] += rounded[i];
            }
        }
        for (i, s) in self.sites.iter().enumerate() {
            if up[i] {
                s.scaler.set_budget(assigned[i]);
            }
            let prev = self.per_site[i].budget.get();
            if (prev - assigned[i] as f64).abs() >= 0.5 {
                self.recorder.record(
                    DecisionEvent::new("rebalancer", "budget_shift")
                        .site(&s.name)
                        .input("from", prev)
                        .input("to", assigned[i] as f64)
                        .input("demand", demand[i])
                        .input("floor", floors[i] as f64)
                        .action(format!(
                            "site '{}' budget {:.0} -> {}",
                            s.name, prev, assigned[i]
                        )),
                );
            }
            self.per_site[i].budget.set(assigned[i] as f64);
        }
    }

    /// Stop the loop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The whole federation: sites, the routing tier and the budget loop.
pub struct Federation {
    pub sites: Vec<Arc<Site>>,
    pub router: Arc<FederationRouter>,
    pub rebalancer: Arc<Rebalancer>,
}

impl Federation {
    /// Look a site up by name.
    pub fn site(&self, name: &str) -> Option<&Arc<Site>> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Chaos hook: kill the named site (see [`Site::fail`]). Returns
    /// false for an unknown name.
    pub fn fail_site(&self, name: &str) -> bool {
        match self.site(name) {
            Some(s) => {
                s.fail();
                true
            }
            None => false,
        }
    }

    /// Chaos hook: recover the named site (see [`Site::recover`]).
    pub fn recover_site(&self, name: &str) -> bool {
        match self.site(name) {
            Some(s) => {
                s.recover();
                true
            }
            None => false,
        }
    }

    /// Running pods across every site.
    pub fn running(&self) -> usize {
        self.sites.iter().map(|s| s.cluster.running()).sum()
    }

    /// Desired pods across every site.
    pub fn desired(&self) -> usize {
        self.sites.iter().map(|s| s.cluster.desired()).sum()
    }

    /// Per-site running pod counts (diagnostics).
    pub fn running_by_site(&self) -> BTreeMap<String, usize> {
        self.sites
            .iter()
            .map(|s| (s.name.clone(), s.cluster.running()))
            .collect()
    }

    /// Tear the federation down: the budget loop first (so it cannot
    /// fight the drain), then every site's scaler and cluster.
    pub fn shutdown(&self) {
        self.rebalancer.shutdown();
        for s in &self.sites {
            s.scaler.shutdown();
            s.cluster.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(warm: usize, q: f64, wan: f64) -> SiteView {
        SiteView { warm, queued_per_warm: q, wan_cost: wan }
    }

    #[test]
    fn order_prefers_cheapest_unsaturated() {
        let views = [v(2, 0.0, 0.03), v(2, 0.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0, 2]);
    }

    #[test]
    fn order_excludes_cold_sites() {
        let views = [v(0, 0.0, 0.0), v(1, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1]);
        assert!(site_order(&[v(0, 0.0, 0.0)], 8.0).is_empty());
    }

    #[test]
    fn saturated_home_spills_to_remote() {
        // Home site (wan 0) saturated, remote warm and idle: remote first.
        let views = [v(2, 10.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0]);
        // Home recovers under the threshold: traffic repatriates.
        let views = [v(2, 3.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order(&views, 8.0), vec![0, 1]);
    }

    #[test]
    fn all_saturated_still_ordered_by_cost() {
        let views = [v(1, 20.0, 0.05), v(1, 30.0, 0.0)];
        assert_eq!(site_order(&views, 8.0), vec![1, 0]);
    }

    #[test]
    fn property_order_never_contains_cold_site() {
        use crate::util::quick::{check, Gen};
        check("site_order excludes warm==0", 300, |g: &mut Gen| {
            let n = g.usize(1..=6);
            let views: Vec<SiteView> = (0..n)
                .map(|_| v(g.usize(0..=3), g.f64(0.0, 20.0), g.f64(0.0, 0.2)))
                .collect();
            let depth = g.f64(0.1, 15.0);
            let order = site_order(&views, depth);
            for &i in &order {
                assert!(views[i].warm > 0, "cold site {i} in order {order:?}");
            }
            // Completeness: every warm site appears exactly once.
            let warm = views.iter().filter(|v| v.warm > 0).count();
            assert_eq!(order.len(), warm);
            let mut seen = order.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), order.len());
        });
    }

    #[test]
    fn property_unsaturated_precede_saturated() {
        use crate::util::quick::{check, Gen};
        check("unsaturated sites sort first", 300, |g: &mut Gen| {
            let n = g.usize(2..=6);
            let views: Vec<SiteView> = (0..n)
                .map(|_| v(g.usize(0..=3), g.f64(0.0, 20.0), g.f64(0.0, 0.2)))
                .collect();
            let depth = g.f64(0.1, 15.0);
            let order = site_order(&views, depth);
            let mut seen_saturated = false;
            for &i in &order {
                let sat = views[i].queued_per_warm >= depth;
                assert!(
                    !(seen_saturated && !sat),
                    "unsaturated site after saturated one: {order:?}"
                );
                seen_saturated |= sat;
            }
        });
    }

    #[test]
    fn derived_depths_follow_budget_share() {
        // Equal budgets reduce to the static depth.
        assert_eq!(derived_depths(8.0, &[4.0, 4.0, 4.0]), vec![8.0, 8.0, 8.0]);
        // A 3:1 budget split moves the knees 3:1 around the base.
        assert_eq!(derived_depths(8.0, &[6.0, 2.0]), vec![12.0, 4.0]);
        // A zero-budget (drained) site clamps at 1.0, never 0.
        let d = derived_depths(8.0, &[8.0, 0.0]);
        assert_eq!(d, vec![16.0, 1.0]);
        // No budget signal at all: static depth everywhere.
        assert_eq!(derived_depths(8.0, &[0.0, 0.0]), vec![8.0, 8.0]);
    }

    #[test]
    fn per_site_knees_change_the_order() {
        // Home (wan 0) queues 5 deep: saturated under a knee of 4,
        // unsaturated under the static 8.
        let views = [v(2, 5.0, 0.0), v(2, 0.0, 0.05)];
        assert_eq!(site_order_with_depths(&views, &[4.0, 8.0]), vec![1, 0]);
        assert_eq!(site_order_with_depths(&views, &[8.0, 8.0]), vec![0, 1]);
        // Uniform depths match the static-rule wrapper.
        assert_eq!(site_order(&views, 8.0), site_order_with_depths(&views, &[8.0, 8.0]));
    }

    #[test]
    fn wan_lookup_is_symmetric_with_fallback() {
        use crate::config::SiteConfig;
        let mut a = SiteConfig { name: "a".into(), ..SiteConfig::default() };
        a.wan.insert("b".into(), Duration::from_millis(30));
        let b = SiteConfig { name: "b".into(), ..SiteConfig::default() };
        let cfg = FederationConfig { sites: vec![a, b], ..FederationConfig::default() };
        assert_eq!(wan_between(&cfg, "a", "b"), Duration::from_millis(30));
        assert_eq!(wan_between(&cfg, "b", "a"), Duration::from_millis(30));
        assert_eq!(wan_between(&cfg, "a", "a"), Duration::ZERO);
        assert_eq!(wan_between(&cfg, "a", "zz"), Duration::ZERO);
    }
}
