//! Metrics pipeline — the Prometheus analogue (§2.3).
//!
//! * [`registry`] — process-wide metric registry: counters, gauges,
//!   histograms, all labelled, lock-cheap on the hot path.
//! * [`store`] — the time-series database: a scraper snapshots the registry
//!   on an interval and windowed queries (avg/rate/quantile over range)
//!   feed the autoscaler trigger and the Fig. 2/3 series.
//! * [`exposition`] — Prometheus text-format rendering plus the HTTP
//!   `/metrics` endpoint.
//! * [`dashboard`] — Grafana stand-in: renders collected series as ASCII
//!   timelines and CSV for the benches.

pub mod dashboard;
pub mod exposition;
pub mod registry;
pub mod store;

pub use registry::{Counter, Gauge, HistogramHandle, Registry};
pub use store::{MetricStore, Scraper};
