//! Metrics pipeline — the Prometheus analogue (§2.3).
//!
//! * [`registry`] — process-wide metric registry: counters, gauges,
//!   histograms, all labelled, lock-cheap on the hot path.
//! * [`store`] — the time-series database: a scraper snapshots the registry
//!   on an interval and windowed queries (avg/rate/quantile over range)
//!   feed the autoscaler trigger and the Fig. 2/3 series.
//! * [`exposition`] — Prometheus text-format rendering plus the HTTP
//!   `/metrics` endpoint.
//! * [`dashboard`] — Grafana stand-in: renders collected series as ASCII
//!   timelines and CSV for the benches.
//!
//! Per-model scaling and placement series (all labelled `model="..."`):
//!
//! * `model_replicas` — instances currently advertising the model (the
//!   warm serving replica count, from the placement controller);
//! * `model_replicas_loading` — replicas still inside their simulated
//!   warm-load window (placed, consuming memory, not yet serving);
//! * `models_loading` (per instance) — serving-set entries mid-load on
//!   one pod (the companion of `models_loaded`);
//! * `model_queue_depth` (per instance × model) — the batcher's
//!   per-model backlog, the queue half of the placement demand signal;
//! * `model_load_events_total` / `model_unload_events_total` — placement
//!   moves applied;
//! * `routed_requests_total` / `routed_unserved_total` — per-model router
//!   traffic (the rate half of the demand signal);
//! * `model_pods_desired` / `model_pods_running` — per-model pod targets
//!   and boot-profile pod counts (cluster, per-model autoscaling mode);
//! * `autoscaler_model_demand` / `autoscaler_model_desired` — the demand
//!   each per-model scaling loop saw and the target it set;
//! * `autoscaler_model_scale_ups_total` / `autoscaler_model_scale_downs_total`
//!   — per-model scale events.
//!
//! Request-priority series (labelled `priority="bulk|standard|critical"`):
//!
//! * `priority_queue_depth` (per instance × priority) — queued requests
//!   per admission lane;
//! * `requests_shed_total` (per instance × priority) — batcher-level
//!   sheds: ingress rejections plus shed-from-bulk evictions;
//! * `batch_preemptions_total` (per instance) — higher-priority batches
//!   served past older lower-priority work;
//! * `gateway_shed_priority_total` — gateway-level sheds by resolved
//!   priority class (rate limiter, pressure gate, overload).

pub mod dashboard;
pub mod exposition;
pub mod registry;
pub mod store;

pub use registry::{Counter, Gauge, HistogramHandle, Registry};
pub use store::{MetricStore, Scraper};
