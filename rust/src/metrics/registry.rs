//! Metric registry: labelled counters, gauges and histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`HistogramHandle`]) are cheap clones
//! holding an `Arc` to shared state; the hot path updates atomics (or a
//! short-lived mutex for histograms) without touching the registry map.
//! Series identity follows the Prometheus convention:
//! `name{label1="v1",label2="v2"}`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::stats::Histogram;

/// Label set, sorted by key (Prometheus identity semantics).
pub type Labels = BTreeMap<String, String>;

/// Build a label set from key/value pairs.
pub fn labels(pairs: &[(&str, &str)]) -> Labels {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Render `name{k="v",...}` (empty labels render as bare name).
pub fn series_id(name: &str, labels: &Labels) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    format!("{name}{{{}}}", parts.join(","))
}

/// Monotonic counter.
#[derive(Clone)]
pub struct Counter {
    value: Arc<AtomicU64>,
}

impl Counter {
    /// Increment by 1.
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge (stores micro-units in an AtomicI64; f64 API).
#[derive(Clone)]
pub struct Gauge {
    micros: Arc<AtomicI64>,
}

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.micros.store((v * 1e6) as i64, Ordering::Relaxed);
    }

    /// Add to the gauge (may be negative).
    pub fn add(&self, v: f64) {
        self.micros.fetch_add((v * 1e6) as i64, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        self.micros.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Histogram handle (mutex-guarded; observations are rare relative to
/// atomic ops and the critical section is tiny).
#[derive(Clone)]
pub struct HistogramHandle {
    inner: Arc<Mutex<Histogram>>,
}

impl HistogramHandle {
    /// Record one observation.
    pub fn observe(&self, v: f64) {
        self.inner.lock().unwrap().observe(v);
    }

    /// Snapshot the histogram.
    pub fn snapshot(&self) -> Histogram {
        self.inner.lock().unwrap().clone()
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(HistogramHandle),
}

/// Process-wide metric registry.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<BTreeMap<String, (String, Labels, Metric)>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str, labels: &Labels) -> Counter {
        let id = series_id(name, labels);
        let mut map = self.inner.lock().unwrap();
        match map.get(&id) {
            Some((_, _, Metric::Counter(c))) => c.clone(),
            Some(_) => panic!("metric '{id}' already registered with a different type"),
            None => {
                let c = Counter { value: Arc::new(AtomicU64::new(0)) };
                map.insert(id, (name.to_string(), labels.clone(), Metric::Counter(c.clone())));
                c
            }
        }
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str, labels: &Labels) -> Gauge {
        let id = series_id(name, labels);
        let mut map = self.inner.lock().unwrap();
        match map.get(&id) {
            Some((_, _, Metric::Gauge(g))) => g.clone(),
            Some(_) => panic!("metric '{id}' already registered with a different type"),
            None => {
                let g = Gauge { micros: Arc::new(AtomicI64::new(0)) };
                map.insert(id, (name.to_string(), labels.clone(), Metric::Gauge(g.clone())));
                g
            }
        }
    }

    /// Get or create a histogram with default latency buckets.
    pub fn histogram(&self, name: &str, labels: &Labels) -> HistogramHandle {
        let id = series_id(name, labels);
        let mut map = self.inner.lock().unwrap();
        match map.get(&id) {
            Some((_, _, Metric::Histogram(h))) => h.clone(),
            Some(_) => panic!("metric '{id}' already registered with a different type"),
            None => {
                let h = HistogramHandle {
                    inner: Arc::new(Mutex::new(Histogram::latency_seconds())),
                };
                map.insert(id, (name.to_string(), labels.clone(), Metric::Histogram(h.clone())));
                h
            }
        }
    }

    /// Snapshot all series as (id, name, labels, sample).
    pub fn snapshot(&self) -> Vec<Sample> {
        let map = self.inner.lock().unwrap();
        map.iter()
            .map(|(id, (name, labels, metric))| Sample {
                id: id.clone(),
                name: name.clone(),
                labels: labels.clone(),
                value: match metric {
                    Metric::Counter(c) => SampleValue::Counter(c.get()),
                    Metric::Gauge(g) => SampleValue::Gauge(g.get()),
                    Metric::Histogram(h) => SampleValue::Histogram(h.snapshot()),
                },
            })
            .collect()
    }

    /// Number of registered series.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True if no series registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One snapshotted series.
pub struct Sample {
    pub id: String,
    pub name: String,
    pub labels: Labels,
    pub value: SampleValue,
}

/// Snapshotted value by metric type.
pub enum SampleValue {
    Counter(u64),
    Gauge(f64),
    Histogram(Histogram),
}

impl SampleValue {
    /// Scalar view: counter/gauge value, histogram mean.
    pub fn scalar(&self) -> f64 {
        match self {
            SampleValue::Counter(v) => *v as f64,
            SampleValue::Gauge(v) => *v,
            SampleValue::Histogram(h) => {
                if h.count() == 0 {
                    0.0
                } else {
                    h.sum() / h.count() as f64
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shared_across_handles() {
        let r = Registry::new();
        let c1 = r.counter("requests_total", &labels(&[("model", "pn")]));
        let c2 = r.counter("requests_total", &labels(&[("model", "pn")]));
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn labels_distinguish_series() {
        let r = Registry::new();
        let a = r.counter("x", &labels(&[("m", "a")]));
        let b = r.counter("x", &labels(&[("m", "b")]));
        a.inc();
        assert_eq!(b.get(), 0);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn gauge_set_add() {
        let r = Registry::new();
        let g = r.gauge("util", &Labels::new());
        g.set(0.5);
        g.add(0.25);
        assert!((g.get() - 0.75).abs() < 1e-9);
        g.add(-0.5);
        assert!((g.get() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn histogram_observe() {
        let r = Registry::new();
        let h = r.histogram("lat", &Labels::new());
        h.observe(0.01);
        h.observe(0.02);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert!((snap.sum() - 0.03).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_conflict_panics() {
        let r = Registry::new();
        let _ = r.counter("m", &Labels::new());
        let _ = r.gauge("m", &Labels::new());
    }

    #[test]
    fn series_id_format() {
        assert_eq!(series_id("up", &Labels::new()), "up");
        assert_eq!(
            series_id("x", &labels(&[("b", "2"), ("a", "1")])),
            "x{a=\"1\",b=\"2\"}" // sorted by key
        );
    }

    #[test]
    fn snapshot_contains_all() {
        let r = Registry::new();
        r.counter("c", &Labels::new()).inc();
        r.gauge("g", &Labels::new()).set(1.5);
        r.histogram("h", &Labels::new()).observe(0.1);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 3);
        assert!((snap.iter().find(|s| s.name == "g").unwrap().value.scalar() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn concurrent_counter_increments() {
        let r = Registry::new();
        let c = r.counter("n", &Labels::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
