//! Time-series store + scraper: the "Prometheus server" half.
//!
//! A [`Scraper`] thread snapshots a [`Registry`](super::registry::Registry)
//! every `interval` of *clock* time and appends points to the
//! [`MetricStore`]. Windowed queries over the store drive the KEDA-style
//! autoscaler trigger ("average request queue latency across Triton
//! servers", §2.4) and regenerate the Fig. 2 timelines.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::registry::{Registry, SampleValue};
use crate::util::clock::Clock;

/// One point in a series: (clock seconds, value).
pub type Point = (f64, f64);

#[derive(Default)]
struct Inner {
    /// series id -> ring of points.
    series: BTreeMap<String, VecDeque<Point>>,
}

/// Append-only time-series store with retention.
#[derive(Clone)]
pub struct MetricStore {
    inner: Arc<Mutex<Inner>>,
    retention: Duration,
}

impl MetricStore {
    /// Store with a retention window.
    pub fn new(retention: Duration) -> Self {
        MetricStore { inner: Arc::new(Mutex::new(Inner::default())), retention }
    }

    /// Append one point to a series, expiring old points.
    pub fn push(&self, series: &str, t: f64, v: f64) {
        let mut inner = self.inner.lock().unwrap();
        let ring = inner.series.entry(series.to_string()).or_default();
        ring.push_back((t, v));
        let horizon = t - self.retention.as_secs_f64();
        while ring.front().is_some_and(|&(pt, _)| pt < horizon) {
            ring.pop_front();
        }
    }

    /// All points of a series within [t0, t1].
    pub fn range(&self, series: &str, t0: f64, t1: f64) -> Vec<Point> {
        let inner = self.inner.lock().unwrap();
        inner
            .series
            .get(series)
            .map(|ring| {
                ring.iter()
                    .filter(|&&(t, _)| t >= t0 && t <= t1)
                    .copied()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Entire retained series.
    pub fn series(&self, series: &str) -> Vec<Point> {
        self.range(series, f64::NEG_INFINITY, f64::INFINITY)
    }

    /// Latest point of a series.
    pub fn latest(&self, series: &str) -> Option<Point> {
        let inner = self.inner.lock().unwrap();
        inner.series.get(series).and_then(|r| r.back().copied())
    }

    /// Average of a series over the trailing `window` ending at `now`.
    pub fn avg_over(&self, series: &str, now: f64, window: Duration) -> Option<f64> {
        let pts = self.range(series, now - window.as_secs_f64(), now);
        if pts.is_empty() {
            return None;
        }
        Some(pts.iter().map(|&(_, v)| v).sum::<f64>() / pts.len() as f64)
    }

    /// Max of a series over the trailing window.
    pub fn max_over(&self, series: &str, now: f64, window: Duration) -> Option<f64> {
        let pts = self.range(series, now - window.as_secs_f64(), now);
        pts.iter().map(|&(_, v)| v).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.max(v)))
        })
    }

    /// Per-second rate of a *counter* series over the trailing window
    /// (Prometheus `rate()`: last-first over elapsed, counter resets not
    /// handled — our counters never reset within a run).
    pub fn rate_over(&self, series: &str, now: f64, window: Duration) -> Option<f64> {
        let pts = self.range(series, now - window.as_secs_f64(), now);
        if pts.len() < 2 {
            return None;
        }
        let (t0, v0) = pts[0];
        let (t1, v1) = pts[pts.len() - 1];
        if t1 <= t0 {
            return None;
        }
        Some((v1 - v0) / (t1 - t0))
    }

    /// Sum of the latest values of all series matching a name prefix
    /// (cheap aggregation across labelled instances).
    pub fn sum_latest_prefix(&self, prefix: &str) -> f64 {
        let inner = self.inner.lock().unwrap();
        inner
            .series
            .iter()
            .filter(|(id, _)| id.starts_with(prefix))
            .filter_map(|(_, ring)| ring.back().map(|&(_, v)| v))
            .sum()
    }

    /// Average of the latest values of all series matching a name prefix.
    pub fn avg_latest_prefix(&self, prefix: &str) -> Option<f64> {
        let inner = self.inner.lock().unwrap();
        let vals: Vec<f64> = inner
            .series
            .iter()
            .filter(|(id, _)| id.starts_with(prefix))
            .filter_map(|(_, ring)| ring.back().map(|&(_, v)| v))
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Ids of all stored series.
    pub fn series_ids(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }
}

/// Background scraper: registry -> store on an interval of clock time.
pub struct Scraper {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Scraper {
    /// Start scraping `registry` into `store` every `interval`.
    ///
    /// Histogram series additionally publish `<id>:avg`, `<id>:p50`,
    /// `<id>:p99` scalar series derived from the snapshot (cumulative) and
    /// `<id>:rate` style derivations are left to query time.
    pub fn start(
        registry: Registry,
        store: MetricStore,
        clock: Clock,
        interval: Duration,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-scraper".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    Self::scrape_once(&registry, &store, &clock);
                    clock.sleep(interval);
                }
            })
            .expect("spawning scraper");
        Scraper { stop, handle: Some(handle) }
    }

    /// One synchronous scrape (also used by tests and simulated-time
    /// drivers that cannot rely on the background thread's cadence).
    pub fn scrape_once(registry: &Registry, store: &MetricStore, clock: &Clock) {
        let t = clock.now_secs();
        for sample in registry.snapshot() {
            match sample.value {
                SampleValue::Counter(v) => store.push(&sample.id, t, v as f64),
                SampleValue::Gauge(v) => store.push(&sample.id, t, v),
                SampleValue::Histogram(h) => {
                    let avg = if h.count() == 0 { 0.0 } else { h.sum() / h.count() as f64 };
                    store.push(&format!("{}:avg", sample.id), t, avg);
                    store.push(&format!("{}:p50", sample.id), t, h.quantile(0.5));
                    store.push(&format!("{}:p99", sample.id), t, h.quantile(0.99));
                    store.push(&format!("{}:count", sample.id), t, h.count() as f64);
                    store.push(&format!("{}:sum", sample.id), t, h.sum());
                }
            }
        }
    }
}

impl Drop for Scraper {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::registry::labels;

    #[test]
    fn push_and_range() {
        let s = MetricStore::new(Duration::from_secs(100));
        s.push("a", 1.0, 10.0);
        s.push("a", 2.0, 20.0);
        s.push("a", 3.0, 30.0);
        assert_eq!(s.range("a", 1.5, 2.5), vec![(2.0, 20.0)]);
        assert_eq!(s.latest("a"), Some((3.0, 30.0)));
        assert_eq!(s.range("missing", 0.0, 10.0), Vec::new());
    }

    #[test]
    fn retention_expires() {
        let s = MetricStore::new(Duration::from_secs(10));
        s.push("a", 0.0, 1.0);
        s.push("a", 100.0, 2.0);
        assert_eq!(s.series("a").len(), 1);
    }

    #[test]
    fn avg_and_max_over() {
        let s = MetricStore::new(Duration::from_secs(100));
        for i in 0..10 {
            s.push("a", i as f64, i as f64);
        }
        assert_eq!(s.avg_over("a", 9.0, Duration::from_secs(4)), Some(7.0)); // 5..=9
        assert_eq!(s.max_over("a", 9.0, Duration::from_secs(100)), Some(9.0));
        assert_eq!(s.avg_over("missing", 9.0, Duration::from_secs(4)), None);
    }

    #[test]
    fn rate_over_counter() {
        let s = MetricStore::new(Duration::from_secs(100));
        s.push("reqs", 0.0, 0.0);
        s.push("reqs", 10.0, 500.0);
        assert_eq!(s.rate_over("reqs", 10.0, Duration::from_secs(60)), Some(50.0));
        assert_eq!(s.rate_over("reqs", 10.0, Duration::from_secs(0)), None);
    }

    #[test]
    fn prefix_aggregation() {
        let s = MetricStore::new(Duration::from_secs(100));
        s.push("util{gpu=\"0\"}", 1.0, 0.5);
        s.push("util{gpu=\"1\"}", 1.0, 0.7);
        s.push("other", 1.0, 9.0);
        assert!((s.sum_latest_prefix("util") - 1.2).abs() < 1e-9);
        assert!((s.avg_latest_prefix("util").unwrap() - 0.6).abs() < 1e-9);
        assert_eq!(s.avg_latest_prefix("nope"), None);
    }

    #[test]
    fn scrape_once_publishes_derived_series() {
        let r = Registry::new();
        let store = MetricStore::new(Duration::from_secs(100));
        let clock = Clock::simulated();
        r.counter("c_total", &labels(&[("m", "pn")])).add(5);
        let h = r.histogram("lat", &labels(&[]));
        h.observe(0.01);
        h.observe(0.03);
        clock.advance(Duration::from_secs(1));
        Scraper::scrape_once(&r, &store, &clock);
        assert_eq!(store.latest("c_total{m=\"pn\"}"), Some((1.0, 5.0)));
        let avg = store.latest("lat:avg").unwrap().1;
        assert!((avg - 0.02).abs() < 1e-9);
        assert_eq!(store.latest("lat:count").unwrap().1, 2.0);
    }

    #[test]
    fn scraper_thread_collects_on_real_clock() {
        let r = Registry::new();
        let store = MetricStore::new(Duration::from_secs(100));
        let clock = Clock::real();
        let g = r.gauge("g", &labels(&[]));
        g.set(42.0);
        {
            let _scraper = Scraper::start(
                r.clone(),
                store.clone(),
                clock,
                Duration::from_millis(5),
            );
            std::thread::sleep(Duration::from_millis(60));
        } // drop joins the thread
        let pts = store.series("g");
        assert!(pts.len() >= 2, "scraped {} points", pts.len());
        assert_eq!(pts.last().unwrap().1, 42.0);
    }
}
