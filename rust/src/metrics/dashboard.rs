//! Dashboard rendering — the Grafana stand-in.
//!
//! The paper ships a pre-configured Grafana dashboard with the Helm chart;
//! here the equivalent is a multi-panel ASCII timeline renderer over the
//! [`MetricStore`](super::store::MetricStore) plus CSV export, used by
//! `examples/autoscale_demo.rs` and the Fig. 2/3 benches.

use crate::metrics::store::MetricStore;
use crate::util::bench::{ascii_chart, Csv};

/// One dashboard panel: a title and the series id it plots.
#[derive(Clone, Debug)]
pub struct Panel {
    pub title: String,
    pub series: String,
}

/// A multi-panel dashboard bound to a store.
pub struct Dashboard {
    panels: Vec<Panel>,
    width: usize,
    height: usize,
}

impl Dashboard {
    /// Dashboard with default panel size.
    pub fn new() -> Self {
        Dashboard { panels: Vec::new(), width: 72, height: 8 }
    }

    /// Set panel dimensions.
    pub fn with_size(mut self, width: usize, height: usize) -> Self {
        self.width = width;
        self.height = height;
        self
    }

    /// Add a panel plotting `series`.
    pub fn panel(mut self, title: &str, series: &str) -> Self {
        self.panels.push(Panel { title: title.to_string(), series: series.to_string() });
        self
    }

    /// Render all panels from the store.
    pub fn render(&self, store: &MetricStore) -> String {
        let mut out = String::new();
        for p in &self.panels {
            let series = store.series(&p.series);
            out.push_str(&ascii_chart(&p.title, &series, self.width, self.height));
            out.push('\n');
        }
        out
    }

    /// Export all panels' series as one aligned CSV (time-joined on the
    /// union of timestamps; missing values carried forward).
    pub fn to_csv(&self, store: &MetricStore) -> Csv {
        let mut headers = vec!["t".to_string()];
        headers.extend(self.panels.iter().map(|p| p.title.clone()));
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut csv = Csv::new(&header_refs);

        let all_series: Vec<Vec<(f64, f64)>> = self
            .panels
            .iter()
            .map(|p| store.series(&p.series))
            .collect();
        let mut times: Vec<f64> = all_series
            .iter()
            .flat_map(|s| s.iter().map(|&(t, _)| t))
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        times.dedup_by(|a, b| (*a - *b).abs() < 1e-9);

        let mut cursors = vec![0usize; all_series.len()];
        let mut last: Vec<f64> = vec![f64::NAN; all_series.len()];
        for t in times {
            let mut row = vec![format!("{t:.3}")];
            for (i, series) in all_series.iter().enumerate() {
                while cursors[i] < series.len() && series[cursors[i]].0 <= t + 1e-9 {
                    last[i] = series[cursors[i]].1;
                    cursors[i] += 1;
                }
                row.push(if last[i].is_nan() {
                    String::new()
                } else {
                    format!("{:.6}", last[i])
                });
            }
            csv.row(&row);
        }
        csv
    }
}

impl Default for Dashboard {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn store_with_data() -> MetricStore {
        let s = MetricStore::new(Duration::from_secs(1000));
        for i in 0..20 {
            s.push("latency", i as f64, (i as f64 * 0.5).sin().abs());
            if i % 2 == 0 {
                s.push("servers", i as f64, 1.0 + (i / 5) as f64);
            }
        }
        s
    }

    #[test]
    fn renders_all_panels() {
        let d = Dashboard::new()
            .panel("Latency (s)", "latency")
            .panel("GPU servers", "servers");
        let out = d.render(&store_with_data());
        assert!(out.contains("Latency (s)"));
        assert!(out.contains("GPU servers"));
        assert!(out.contains('*'));
    }

    #[test]
    fn csv_time_joins_series() {
        let d = Dashboard::new()
            .panel("lat", "latency")
            .panel("srv", "servers");
        let csv = d.to_csv(&store_with_data());
        let lines: Vec<&str> = csv.contents().lines().collect();
        assert_eq!(lines[0], "t,lat,srv");
        // 20 union timestamps
        assert_eq!(lines.len(), 21);
        // carried-forward srv value on odd timestamps
        let row3: Vec<&str> = lines[4].split(',').collect(); // t=3
        assert!(!row3[2].is_empty());
    }

    #[test]
    fn empty_series_renders_placeholder() {
        let s = MetricStore::new(Duration::from_secs(10));
        let d = Dashboard::new().panel("empty", "nothing");
        let out = d.render(&s);
        assert!(out.contains("empty series"));
    }
}
