//! Prometheus text exposition format + the `/metrics` HTTP endpoint.
//!
//! The render follows the text format an actual Prometheus server would
//! scrape (`# TYPE` lines, histogram `_bucket`/`_sum`/`_count` expansion
//! with cumulative buckets and `le` labels). The HTTP server is a minimal
//! HTTP/1.1 responder — enough for `curl` and for a real Prometheus scrape
//! job, which is all the paper's stack needs from it.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::registry::{Registry, SampleValue};

/// Render the registry in Prometheus text format.
pub fn render(registry: &Registry) -> String {
    let mut out = String::new();
    let mut last_name = String::new();
    for sample in registry.snapshot() {
        match &sample.value {
            SampleValue::Counter(v) => {
                if sample.name != last_name {
                    out.push_str(&format!("# TYPE {} counter\n", sample.name));
                    last_name = sample.name.clone();
                }
                out.push_str(&format!("{} {}\n", sample.id, v));
            }
            SampleValue::Gauge(v) => {
                if sample.name != last_name {
                    out.push_str(&format!("# TYPE {} gauge\n", sample.name));
                    last_name = sample.name.clone();
                }
                out.push_str(&format!("{} {}\n", sample.id, v));
            }
            SampleValue::Histogram(h) => {
                if sample.name != last_name {
                    out.push_str(&format!("# TYPE {} histogram\n", sample.name));
                    last_name = sample.name.clone();
                }
                let base_labels: Vec<String> = sample
                    .labels
                    .iter()
                    .map(|(k, v)| format!("{k}=\"{v}\""))
                    .collect();
                let with_le = |le: &str| -> String {
                    let mut ls = base_labels.clone();
                    ls.push(format!("le=\"{le}\""));
                    format!("{}_bucket{{{}}}", sample.name, ls.join(","))
                };
                let mut cum = 0u64;
                for (i, &c) in h.counts().iter().enumerate() {
                    cum += c;
                    let le = if i < h.bounds().len() {
                        format!("{}", h.bounds()[i])
                    } else {
                        "+Inf".to_string()
                    };
                    out.push_str(&format!("{} {}\n", with_le(&le), cum));
                }
                let suffix = if base_labels.is_empty() {
                    String::new()
                } else {
                    format!("{{{}}}", base_labels.join(","))
                };
                out.push_str(&format!("{}_sum{} {}\n", sample.name, suffix, h.sum()));
                out.push_str(&format!("{}_count{} {}\n", sample.name, suffix, h.count()));
            }
        }
    }
    out
}

/// Producer of the `/debug` section body — a plain-text diagnostic
/// rendered on demand (the control-plane explain view in the full
/// deployment). Kept as a trait object so the metrics layer stays
/// ignorant of the telemetry types feeding it.
pub type DebugProvider = Arc<dyn Fn() -> String + Send + Sync>;

/// Minimal HTTP/1.1 server exposing `/metrics` (and `/healthz`, plus a
/// `/debug` diagnostic section when a provider is wired).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind and serve in a background thread.
    pub fn start(listen: &str, registry: Registry) -> Result<Self> {
        Self::start_with_debug(listen, registry, None)
    }

    /// Like [`MetricsServer::start`], additionally serving `debug()`'s
    /// output under `/debug` (404 when no provider is given).
    pub fn start_with_debug(
        listen: &str,
        registry: Registry,
        debug: Option<DebugProvider>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding metrics endpoint {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let _ = stream.set_nodelay(true);
                            let mut buf = [0u8; 2048];
                            let n = stream.read(&mut buf).unwrap_or(0);
                            let req = String::from_utf8_lossy(&buf[..n]);
                            let path = req
                                .lines()
                                .next()
                                .and_then(|l| l.split_whitespace().nth(1))
                                .unwrap_or("/");
                            let (status, body) = match path {
                                "/metrics" => ("200 OK", render(&registry)),
                                "/healthz" => ("200 OK", "ok\n".to_string()),
                                "/debug" => match &debug {
                                    Some(d) => ("200 OK", d()),
                                    None => {
                                        ("404 Not Found", "no debug provider\n".to_string())
                                    }
                                },
                                _ => ("404 Not Found", "not found\n".to_string()),
                            };
                            let resp = format!(
                                "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                                body.len(),
                                body
                            );
                            let _ = stream.write_all(resp.as_bytes());
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawning metrics http thread");
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::registry::labels;

    #[test]
    fn render_counter_and_gauge() {
        let r = Registry::new();
        r.counter("requests_total", &labels(&[("model", "pn")])).add(7);
        r.gauge("gpu_utilization", &labels(&[("gpu", "0")])).set(0.75);
        let text = render(&r);
        assert!(text.contains("# TYPE requests_total counter"));
        assert!(text.contains("requests_total{model=\"pn\"} 7"));
        assert!(text.contains("gpu_utilization{gpu=\"0\"} 0.75"));
    }

    #[test]
    fn render_histogram_cumulative() {
        let r = Registry::new();
        let h = r.histogram("latency_seconds", &labels(&[]));
        h.observe(0.001);
        h.observe(0.004);
        h.observe(100.0);
        let text = render(&r);
        assert!(text.contains("# TYPE latency_seconds histogram"));
        assert!(text.contains("le=\"+Inf\"} 3"));
        assert!(text.contains("latency_seconds_count 3"));
        // buckets must be cumulative: find two bucket lines and check order
        let buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("latency_seconds_bucket"))
            .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
            .collect();
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "{buckets:?}");
    }

    #[test]
    fn http_endpoint_serves_metrics() {
        let r = Registry::new();
        r.counter("up_total", &labels(&[])).inc();
        let server = MetricsServer::start("127.0.0.1:0", r).unwrap();
        let addr = server.addr();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("up_total 1"));
    }

    #[test]
    fn http_endpoint_serves_debug_section() {
        let r = Registry::new();
        let provider: DebugProvider = Arc::new(|| "== control-plane explain ==\n".to_string());
        let server = MetricsServer::start_with_debug("127.0.0.1:0", r, Some(provider)).unwrap();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(b"GET /debug HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("control-plane explain"));
        // Without a provider the path 404s.
        let bare = MetricsServer::start("127.0.0.1:0", Registry::new()).unwrap();
        let mut stream = std::net::TcpStream::connect(bare.addr()).unwrap();
        stream
            .write_all(b"GET /debug HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    }

    #[test]
    fn http_endpoint_404() {
        let r = Registry::new();
        let server = MetricsServer::start("127.0.0.1:0", r).unwrap();
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(b"GET /nope HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"));
    }
}
