//! Shared experiment drivers for the paper's evaluation (§4).
//!
//! The Fig. 2 and Fig. 3 benches and the `autoscale_demo` example all run
//! the same experiment shape — a 1 → N → 1 perf_analyzer schedule against
//! a deployment while sampling the three paper series (inference rate,
//! average queue latency, GPU server count). This module owns that
//! driver so the benches stay declarative.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::DeploymentConfig;
use crate::deployment::Deployment;
use crate::metrics::store::Point;
use crate::util::stats::Summary;
use crate::workload::{ClientPool, RunReport, Schedule, WorkloadSpec};

/// Sampled series + workload report from one experiment run.
pub struct ExperimentResult {
    /// (clock secs, rows/s) — the paper's "inference rate".
    pub rate: Vec<Point>,
    /// (clock secs, avg queue latency secs).
    pub latency: Vec<Point>,
    /// (clock secs, Ready GPU servers).
    pub servers: Vec<Point>,
    /// (clock secs, mean GPU utilization 0..1).
    pub utilization: Vec<Point>,
    /// Client-side per-phase statistics.
    pub report: RunReport,
    /// Mean GPU utilization over the run, weighted by *allocated* servers
    /// (the Fig. 3 y-axis: a parked-but-idle GPU counts against you).
    pub mean_utilization: f64,
    /// Client-observed end-to-end latency across the run.
    pub overall_latency: Summary,
    /// Peak Ready servers observed.
    pub peak_servers: usize,
}

/// Drive `schedule` against a booted deployment, sampling series every
/// `sample_every` of clock time.
pub fn run_schedule(
    d: &Deployment,
    spec: WorkloadSpec,
    schedule: &Schedule,
    sample_every: Duration,
) -> Result<ExperimentResult> {
    let stop = Arc::new(AtomicBool::new(false));
    let rows_per_request = spec.batch_rows;

    // Sampler thread: aggregates instance series into experiment series.
    let sampler = {
        let store = d.store.clone();
        let cluster = Arc::clone(&d.cluster);
        let clock = d.clock.clone();
        let stop = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("experiment-sampler".into())
            .spawn(move || {
                let mut out: (Vec<Point>, Vec<Point>, Vec<Point>, Vec<Point>) =
                    Default::default();
                while !stop.load(Ordering::SeqCst) {
                    let t = clock.now_secs();
                    let rows = store.sum_latest_prefix("inference_rows_total");
                    store.push("exp_rows_total", t, rows);
                    let rate = store
                        .rate_over("exp_rows_total", t, Duration::from_secs(20))
                        .unwrap_or(0.0);
                    out.0.push((t, rate));
                    out.1.push((
                        t,
                        store.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0),
                    ));
                    out.2.push((t, cluster.running() as f64));
                    out.3.push((
                        t,
                        store.avg_latest_prefix("gpu_utilization").unwrap_or(0.0),
                    ));
                    clock.sleep(sample_every);
                }
                out
            })
            .expect("spawning sampler")
    };

    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(schedule);

    stop.store(true, Ordering::SeqCst);
    let (rate, latency, servers, utilization) = sampler.join().expect("sampler panicked");

    // Fig. 3 aggregates.
    let mean_utilization = if utilization.is_empty() {
        0.0
    } else {
        utilization.iter().map(|&(_, v)| v).sum::<f64>() / utilization.len() as f64
    };
    let peak_servers = servers.iter().map(|&(_, v)| v as usize).max().unwrap_or(0);
    let overall_latency = report.overall_latency.clone();
    let _ = rows_per_request;

    Ok(ExperimentResult {
        rate,
        latency,
        servers,
        utilization,
        report,
        mean_utilization,
        overall_latency,
        peak_servers,
    })
}

/// Boot `cfg`, wait for the expected replicas, run, tear down.
pub fn run_deployment(
    cfg: DeploymentConfig,
    spec: WorkloadSpec,
    schedule: &Schedule,
    sample_every: Duration,
) -> Result<ExperimentResult> {
    let boot_replicas = if cfg.autoscaler.enabled {
        cfg.server.replicas.clamp(cfg.autoscaler.min_replicas, cfg.autoscaler.max_replicas)
    } else {
        cfg.server.replicas
    };
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(
        d.wait_ready(boot_replicas, Duration::from_secs(120)),
        "deployment did not become ready"
    );
    let result = run_schedule(&d, spec, schedule, sample_every)?;
    d.down();
    Ok(result)
}

/// The paper's Fig. 2/3 deployment config, parameterized for the benches.
///
/// `static_replicas = None` enables the autoscaler (the "dynamic"
/// configuration); `Some(n)` pins n GPU servers (the static baselines).
pub fn fig_config(
    time_scale: f64,
    static_replicas: Option<usize>,
    phase: Duration,
) -> DeploymentConfig {
    use crate::config::*;
    use std::path::PathBuf;

    // Scale-down stabilization sized relative to the phase so the
    // scale-down is visible within phase 3.
    let stabilization = Duration::from_secs_f64(phase.as_secs_f64() * 0.15);
    DeploymentConfig {
        name: match static_replicas {
            None => "fig-dynamic".into(),
            Some(n) => format!("fig-static-{n}"),
        },
        server: ServerConfig {
            replicas: static_replicas.unwrap_or(1),
            models: vec![ModelConfig {
                name: "particlenet".into(),
                max_queue_delay: Duration::from_millis(5),
                preferred_batch: 16,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(5),
                    per_row: Duration::from_micros(1500),
                },
            }],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_secs(10),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
        },
        gateway: GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::LeastConnection,
            max_inflight_per_instance: 64,
            ..GatewayConfig::default()
        },
        autoscaler: AutoscalerConfig {
            enabled: static_replicas.is_none(),
            metric: "queue_latency_avg:30".into(),
            // With the T4 service model (29 ms per 16-row batch) the
            // per-request queue wait is ~230 ms at 1 server under ten
            // clients, ~38 ms at 3, ~13 ms at 4: threshold 25 ms settles
            // the autoscaler at 4-5 servers, the "optimal trade-off" knee.
            threshold: 0.025,
            scale_down_ratio: 0.3,
            min_replicas: 1,
            max_replicas: 10,
            poll_interval: Duration::from_secs(5),
            scale_up_cooldown: Duration::from_secs(20),
            scale_down_stabilization: stabilization,
            step: 1,
        },
        cluster: ClusterConfig {
            nodes: 4,
            gpus_per_node: 3,
            pod_start_delay: Duration::from_secs(20),
            termination_grace: Duration::from_secs(5),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(2),
            retention: Duration::from_secs(7200),
            tracing: false,
        },
        time_scale,
    }
}

/// The paper's Fig. 2 workload spec (ParticleNet, 16 rows/request, light
/// think time so one client ≈ half a T4).
pub fn fig_workload() -> WorkloadSpec {
    let mut spec = WorkloadSpec::new("particlenet", 16, vec![64, 7]);
    spec.think_time = Duration::from_millis(30);
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_config_validates() {
        fig_config(4.0, None, Duration::from_secs(300)).validate().unwrap();
        fig_config(8.0, Some(10), Duration::from_secs(60)).validate().unwrap();
    }

    #[test]
    fn short_dynamic_run_scales_up() {
        // Compressed Fig. 2: 30x time scale, 60-second clock phases. The
        // 10-client phase must trigger at least one scale-up.
        let phase = Duration::from_secs(90);
        let cfg = fig_config(30.0, None, phase);
        let schedule = Schedule::new()
            .phase(1, Duration::from_secs(30))
            .phase(10, phase);
        let result =
            run_deployment(cfg, fig_workload(), &schedule, Duration::from_secs(5)).unwrap();
        assert!(
            result.peak_servers >= 2,
            "no scale-up observed (peak {})",
            result.peak_servers
        );
        assert!(result.report.total_ok > 0);
    }
}
