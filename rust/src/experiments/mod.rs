//! Shared experiment drivers for the paper's evaluation (§4).
//!
//! The Fig. 2 and Fig. 3 benches and the `autoscale_demo` example all run
//! the same experiment shape — a 1 → N → 1 perf_analyzer schedule against
//! a deployment while sampling the three paper series (inference rate,
//! average queue latency, GPU server count). This module owns that
//! driver so the benches stay declarative.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::DeploymentConfig;
use crate::deployment::Deployment;
use crate::metrics::store::Point;
use crate::util::stats::Summary;
use crate::workload::{ClientPool, RunReport, Schedule, WorkloadSpec};

/// Sampled series + workload report from one experiment run.
pub struct ExperimentResult {
    /// (clock secs, rows/s) — the paper's "inference rate".
    pub rate: Vec<Point>,
    /// (clock secs, avg queue latency secs).
    pub latency: Vec<Point>,
    /// (clock secs, Ready GPU servers).
    pub servers: Vec<Point>,
    /// (clock secs, mean GPU utilization 0..1).
    pub utilization: Vec<Point>,
    /// Client-side per-phase statistics.
    pub report: RunReport,
    /// Mean GPU utilization over the run, weighted by *allocated* servers
    /// (the Fig. 3 y-axis: a parked-but-idle GPU counts against you).
    pub mean_utilization: f64,
    /// Client-observed end-to-end latency across the run.
    pub overall_latency: Summary,
    /// Peak Ready servers observed.
    pub peak_servers: usize,
}

/// Drive `schedule` against a booted deployment, sampling series every
/// `sample_every` of clock time.
pub fn run_schedule(
    d: &Deployment,
    spec: WorkloadSpec,
    schedule: &Schedule,
    sample_every: Duration,
) -> Result<ExperimentResult> {
    let stop = Arc::new(AtomicBool::new(false));
    let rows_per_request = spec.batch_rows;

    // Sampler thread: aggregates instance series into experiment series.
    let sampler = {
        let store = d.store.clone();
        let cluster = Arc::clone(&d.cluster);
        let clock = d.clock.clone();
        let stop = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("experiment-sampler".into())
            .spawn(move || {
                let mut out: (Vec<Point>, Vec<Point>, Vec<Point>, Vec<Point>) =
                    Default::default();
                while !stop.load(Ordering::SeqCst) {
                    let t = clock.now_secs();
                    let rows = store.sum_latest_prefix("inference_rows_total");
                    store.push("exp_rows_total", t, rows);
                    let rate = store
                        .rate_over("exp_rows_total", t, Duration::from_secs(20))
                        .unwrap_or(0.0);
                    out.0.push((t, rate));
                    out.1.push((
                        t,
                        store.avg_latest_prefix("queue_latency_seconds").unwrap_or(0.0),
                    ));
                    out.2.push((t, cluster.running() as f64));
                    out.3.push((
                        t,
                        store.avg_latest_prefix("gpu_utilization").unwrap_or(0.0),
                    ));
                    clock.sleep(sample_every);
                }
                out
            })
            .expect("spawning sampler")
    };

    let pool = ClientPool::new(&d.endpoint(), spec, d.clock.clone());
    let report = pool.run(schedule);

    stop.store(true, Ordering::SeqCst);
    let (rate, latency, servers, utilization) = sampler.join().expect("sampler panicked");

    // Fig. 3 aggregates.
    let mean_utilization = if utilization.is_empty() {
        0.0
    } else {
        utilization.iter().map(|&(_, v)| v).sum::<f64>() / utilization.len() as f64
    };
    let peak_servers = servers.iter().map(|&(_, v)| v as usize).max().unwrap_or(0);
    let overall_latency = report.overall_latency.clone();
    let _ = rows_per_request;

    Ok(ExperimentResult {
        rate,
        latency,
        servers,
        utilization,
        report,
        mean_utilization,
        overall_latency,
        peak_servers,
    })
}

/// Boot `cfg`, wait for the expected replicas, run, tear down.
pub fn run_deployment(
    cfg: DeploymentConfig,
    spec: WorkloadSpec,
    schedule: &Schedule,
    sample_every: Duration,
) -> Result<ExperimentResult> {
    let boot_replicas = if cfg.autoscaler.enabled {
        cfg.server.replicas.clamp(cfg.autoscaler.min_replicas, cfg.autoscaler.max_replicas)
    } else {
        cfg.server.replicas
    };
    let d = Deployment::up(cfg)?;
    anyhow::ensure!(
        d.wait_ready(boot_replicas, Duration::from_secs(120)),
        "deployment did not become ready"
    );
    let result = run_schedule(&d, spec, schedule, sample_every)?;
    d.down();
    Ok(result)
}

/// The paper's Fig. 2/3 deployment config, parameterized for the benches.
///
/// `static_replicas = None` enables the autoscaler (the "dynamic"
/// configuration); `Some(n)` pins n GPU servers (the static baselines).
pub fn fig_config(
    time_scale: f64,
    static_replicas: Option<usize>,
    phase: Duration,
) -> DeploymentConfig {
    use crate::config::*;
    use std::path::PathBuf;

    // Scale-down stabilization sized relative to the phase so the
    // scale-down is visible within phase 3.
    let stabilization = Duration::from_secs_f64(phase.as_secs_f64() * 0.15);
    DeploymentConfig {
        name: match static_replicas {
            None => "fig-dynamic".into(),
            Some(n) => format!("fig-static-{n}"),
        },
        server: ServerConfig {
            replicas: static_replicas.unwrap_or(1),
            models: vec![ModelConfig {
                name: "particlenet".into(),
                max_queue_delay: Duration::from_millis(5),
                preferred_batch: 16,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(5),
                    per_row: Duration::from_micros(1500),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_secs(10),
            execution: ExecutionMode::Simulated,
            queue_capacity: 512,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::LeastConnection,
            max_inflight_per_instance: 64,
            ..GatewayConfig::default()
        },
        autoscaler: AutoscalerConfig {
            enabled: static_replicas.is_none(),
            metric: "queue_latency_avg:30".into(),
            // With the T4 service model (29 ms per 16-row batch) the
            // per-request queue wait is ~230 ms at 1 server under ten
            // clients, ~38 ms at 3, ~13 ms at 4: threshold 25 ms settles
            // the autoscaler at 4-5 servers, the "optimal trade-off" knee.
            threshold: 0.025,
            scale_down_ratio: 0.3,
            min_replicas: 1,
            max_replicas: 10,
            poll_interval: Duration::from_secs(5),
            scale_up_cooldown: Duration::from_secs(20),
            scale_down_stabilization: stabilization,
            step: 1,
            per_model: PerModelScalingConfig::default(),
        },
        cluster: ClusterConfig {
            nodes: 4,
            gpus_per_node: 3,
            pod_start_delay: Duration::from_secs(20),
            termination_grace: Duration::from_secs(5),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(2),
            retention: Duration::from_secs(7200),
            tracing: false,
        },
        model_placement: ModelPlacementConfig::default(),
        engines: EnginesConfig::default(),
        observability: ObservabilityConfig::default(),
        rpc: Default::default(),
        federation: Default::default(),
        time_scale,
    }
}

/// The paper's Fig. 2 workload spec (ParticleNet, 16 rows/request, light
/// think time so one client ≈ half a T4).
pub fn fig_workload() -> WorkloadSpec {
    let mut spec = WorkloadSpec::new("particlenet", 16, vec![64, 7]);
    spec.think_time = Duration::from_millis(30);
    spec
}

/// Two-model deployment for the modelmesh ablation: four instances whose
/// memory budget fits exactly ONE model (particlenet ~87 KB, icecube_cnn
/// ~152 KB of f32 weights, budget 0.2 MB), so placement must partition
/// the fleet. `policy` selects the arm: `Static` pins the boot-time
/// balanced rotation (2+2), `Dynamic` lets the controller move replicas
/// toward demand.
pub fn modelmesh_config(
    time_scale: f64,
    policy: crate::config::PlacementPolicy,
) -> DeploymentConfig {
    use crate::config::*;
    use std::path::PathBuf;

    let service = ServiceModelConfig {
        base: Duration::from_millis(5),
        per_row: Duration::from_micros(1500),
    };
    let model = |name: &str| ModelConfig {
        name: name.into(),
        max_queue_delay: Duration::from_millis(2),
        preferred_batch: 8,
        service_model: service,
        load_delay: None,
        backends: Vec::new(),
        ..ModelConfig::default()
    };
    DeploymentConfig {
        name: format!("mesh-{}", policy.name()),
        server: ServerConfig {
            replicas: 4,
            models: vec![model("particlenet"), model("icecube_cnn")],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_millis(500),
            execution: ExecutionMode::Simulated,
            // Small queues + a small in-flight cap: overload on the hot
            // model's pool shows up as sheds rather than unbounded queues.
            queue_capacity: 8,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::LeastConnection,
            max_inflight_per_instance: 4,
            ..GatewayConfig::default()
        },
        autoscaler: AutoscalerConfig {
            enabled: false,
            max_replicas: 4, // cluster capacity below
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 2,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(500),
            termination_grace: Duration::from_secs(1),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(7200),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            policy,
            memory_budget_mb: 0.2,
            // Hot per-replica demand sits in the hundreds of req/s, cold
            // in the tens: thresholds bracket them so the controller
            // settles at 3 hot + 1 cold and holds (hysteresis band).
            load_threshold: 150.0,
            unload_threshold: 60.0,
            cooldown: Duration::from_secs(5),
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        },
        engines: EnginesConfig::default(),
        observability: ObservabilityConfig::default(),
        rpc: Default::default(),
        federation: Default::default(),
        time_scale,
    }
}

/// Two-model deployment for the per-model autoscaling ablation
/// (`benches/per_model_autoscale.rs`): same 90/10 skew and one-model
/// memory budget as the modelmesh ablation, but with the autoscaler on
/// and an equal total-pod budget in both arms. `per_model = false` is
/// the global arm (one queue-latency-driven target; new pods boot with
/// the balanced rotation placement, so only every other pod helps the
/// hot model); `per_model = true` runs one scaling loop per model fed by
/// placement demand, and hot-model pods boot advertising only that model.
pub fn per_model_autoscale_config(time_scale: f64, per_model: bool) -> DeploymentConfig {
    use crate::config::*;

    let mut cfg = modelmesh_config(time_scale, PlacementPolicy::Static);
    cfg.name = if per_model { "scale-per-model".into() } else { "scale-global".into() };
    cfg.server.replicas = 2;
    cfg.cluster = ClusterConfig {
        nodes: 4,
        gpus_per_node: 2,
        pod_start_delay: Duration::from_millis(500),
        termination_grace: Duration::from_secs(1),
        pod_failure_rate: 0.0,
    };
    cfg.autoscaler = AutoscalerConfig {
        enabled: true,
        // Global arm trigger: average queue wait over a short window.
        metric: "queue_latency_avg:5".into(),
        threshold: 0.02,
        scale_down_ratio: 0.2,
        min_replicas: 2,
        // The shared pod budget: BOTH arms may run at most 6 pods.
        max_replicas: 6,
        poll_interval: Duration::from_secs(1),
        scale_up_cooldown: Duration::from_secs(3),
        // No scale-down churn within the measured run.
        scale_down_stabilization: Duration::from_secs(300),
        step: 1,
        per_model: PerModelScalingConfig {
            enabled: per_model,
            // Per-replica demand (req/s + queued); a saturated simulated
            // GPU serves ~470 single-row req/s, so a hot replica sits
            // well above this and a 10% cold stream well below.
            threshold: 200.0,
            min_replicas: 1,
            max_replicas: 5,
        },
    };
    cfg
}

/// Deployment for the warm-load ablation
/// (`benches/warm_load_ablation.rs`): the same two-model fleet and 90/10
/// skew machinery as the modelmesh ablation, with two deliberate twists.
/// The per-instance memory budget fits BOTH models, so mixed
/// per-instance queues are the steady state — exactly where batch
/// admission matters — and the cold model (icecube_cnn) batches over a
/// wide window it rarely fills under skew, so `fifo` admission stalls an
/// instance for the whole window whenever a cold request reaches the
/// head while `affinity` serves the hot model's ready batches past it.
/// `load_delay` prices placement moves (0 = the instant-load baseline:
/// thrash is free); `batch_mode` selects the admission arm.
pub fn warm_load_config(
    time_scale: f64,
    load_delay: Duration,
    batch_mode: crate::config::BatchMode,
) -> DeploymentConfig {
    let mut cfg = modelmesh_config(time_scale, crate::config::PlacementPolicy::Dynamic);
    cfg.name = format!(
        "warmload-{}-{}",
        if load_delay.is_zero() { "instant" } else { "costed" },
        batch_mode.name()
    );
    cfg.server.batch_mode = batch_mode;
    // Both models fit together (87 KB + 152 KB < 450 KB): placement
    // only moves replicas when demand says so, not because memory
    // forces a partition.
    cfg.model_placement.memory_budget_mb = 0.45;
    cfg.model_placement.load_delay = load_delay;
    // Threshold low enough that the flipped model's concentrated demand
    // clears it even after the warm-load discount and even in the
    // degraded fifo arm — the flip must force real (priced) loads.
    cfg.model_placement.load_threshold = 100.0;
    // Wide, rarely-filled batching window on the cold model: the
    // head-of-line hazard fifo admission pays and affinity avoids.
    cfg.server.models[1].max_queue_delay = Duration::from_millis(50);
    cfg.server.models[1].preferred_batch = 64;
    cfg
}

/// The skewed two-model workload for the modelmesh ablation:
/// `hot_fraction` of requests hit particlenet, the rest icecube_cnn,
/// single-row requests with a light think time.
pub fn modelmesh_workload(addr: &str, hot_fraction: f64, clock: crate::util::clock::Clock)
    -> crate::workload::MixedPool {
    let mut hot = WorkloadSpec::new("particlenet", 1, vec![64, 7]);
    hot.think_time = Duration::from_millis(5);
    let mut cold = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
    cold.think_time = Duration::from_millis(5);
    crate::workload::MixedPool::hot_cold(addr, hot, cold, hot_fraction, clock, 0xAB1A7E)
}

/// Deployment for the backend ablation (`benches/backend_ablation.rs`):
/// a four-pod budget split between GPU-class and CPU-class pods
/// (`cpu_pods` of them). Two models share the fleet under skewed
/// traffic: the hot `particlenet` runs anywhere (pjrt preferred,
/// onnx-sim fallback), while the cold-but-constant `icecube_cnn` is a
/// cheap **CPU-only** model (`backends: [onnx-sim]` — the classic
/// ONNX-on-CPU auxiliary model no GPU engine exists for). A
/// homogeneous-GPU fleet (`cpu_pods = 0`) therefore cannot place the
/// cold model at all and sheds its whole stream; a mixed fleet serves
/// it on the CPU pods — and boot-places the hot model there too via an
/// onnx-sim *fallback* (counted in `backend_fallback_total`), since
/// pjrt has no capacity on a CPU pod.
pub fn backend_config(time_scale: f64, cpu_pods: usize) -> DeploymentConfig {
    use crate::config::*;
    use std::path::PathBuf;

    assert!(cpu_pods < 4, "the ablation keeps a 4-pod budget");
    let hot = ModelConfig {
        name: "particlenet".into(),
        max_queue_delay: Duration::from_millis(2),
        preferred_batch: 8,
        service_model: ServiceModelConfig {
            base: Duration::from_millis(5),
            per_row: Duration::from_micros(1500),
        },
        load_delay: None,
        backends: vec!["pjrt".into(), "onnx-sim".into()],
        ..ModelConfig::default()
    };
    let cold = ModelConfig {
        name: "icecube_cnn".into(),
        max_queue_delay: Duration::from_millis(2),
        preferred_batch: 8,
        // Cheap auxiliary model: a CPU backend serves it comfortably.
        service_model: ServiceModelConfig {
            base: Duration::from_millis(1),
            per_row: Duration::from_micros(100),
        },
        load_delay: None,
        backends: vec!["onnx-sim".into()],
        ..ModelConfig::default()
    };
    DeploymentConfig {
        name: if cpu_pods == 0 {
            "backend-gpu-only".into()
        } else {
            format!("backend-mixed-{cpu_pods}cpu")
        },
        server: ServerConfig {
            replicas: 4 - cpu_pods,
            models: vec![hot, cold],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_millis(500),
            execution: ExecutionMode::Simulated,
            queue_capacity: 64,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::LeastConnection,
            max_inflight_per_instance: 8,
            ..GatewayConfig::default()
        },
        autoscaler: AutoscalerConfig {
            enabled: false,
            max_replicas: 4, // cluster capacity below
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 2,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(500),
            termination_grace: Duration::from_secs(1),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(7200),
            tracing: false,
        },
        model_placement: ModelPlacementConfig {
            policy: PlacementPolicy::Dynamic,
            // Both models fit one instance together: the partition is
            // driven by backend compatibility, not memory.
            memory_budget_mb: 0.45,
            load_threshold: 100.0,
            unload_threshold: 40.0,
            cooldown: Duration::from_secs(5),
            demand_window: Duration::from_secs(10),
            min_replicas_per_model: 1,
            load_delay: Duration::ZERO,
        },
        engines: EnginesConfig {
            cpu_replicas: cpu_pods,
            // A CPU core runs the cheap model ~2x slower than the GPU
            // service model — adequate for an auxiliary model.
            onnx_slowdown: 2.0,
            ..EnginesConfig::default()
        },
        observability: ObservabilityConfig::default(),
        rpc: Default::default(),
        federation: Default::default(),
        time_scale,
    }
}

/// The skewed two-model workload for the backend ablation: 70% hot
/// (GPU-capable particlenet), 30% cold (CPU-only icecube_cnn), 1-row
/// requests with a light think time.
pub fn backend_workload(
    addr: &str,
    clock: crate::util::clock::Clock,
) -> crate::workload::MixedPool {
    let mut hot = WorkloadSpec::new("particlenet", 1, vec![64, 7]);
    hot.think_time = Duration::from_millis(5);
    let mut cold = WorkloadSpec::new("icecube_cnn", 1, vec![16, 16, 3]);
    cold.think_time = Duration::from_millis(5);
    crate::workload::MixedPool::hot_cold(addr, hot, cold, 0.7, clock, 0xBACE)
}

/// Deployment for the priority ablation (`benches/priority_ablation.rs`):
/// two fixed simulated GPU servers serving one model, sized so the bulk
/// stream saturates them and queues stay near the row bound — exactly
/// where the admission lanes, shed-from-bulk eviction, and priority
/// selection matter. No autoscaler and no mesh: the pod budget is equal
/// by construction, so the only difference between bench arms is how the
/// *same traffic* is tagged.
pub fn priority_config(time_scale: f64, name: &str) -> DeploymentConfig {
    use crate::config::*;
    use std::path::PathBuf;

    DeploymentConfig {
        name: name.into(),
        server: ServerConfig {
            replicas: 2,
            models: vec![ModelConfig {
                name: "particlenet".into(),
                max_queue_delay: Duration::from_millis(5),
                preferred_batch: 16,
                service_model: ServiceModelConfig {
                    base: Duration::from_millis(5),
                    per_row: Duration::from_micros(1500),
                },
                load_delay: None,
                backends: Vec::new(),
                ..ModelConfig::default()
            }],
            repository: PathBuf::from("artifacts"),
            startup_delay: Duration::from_millis(500),
            execution: ExecutionMode::Simulated,
            // Row-bounded admission: ~4 preferred batches of backlog per
            // instance before shedding kicks in.
            queue_capacity: 64,
            util_window: 10.0,
            batch_mode: Default::default(),
            priorities: Default::default(),
        },
        gateway: GatewayConfig {
            listen: "127.0.0.1:0".into(),
            lb_policy: LbPolicy::LeastConnection,
            // Uncapped in-flight: overload lands in the batcher, where
            // the lanes decide who waits and who is shed.
            max_inflight_per_instance: 0,
            ..GatewayConfig::default()
        },
        autoscaler: AutoscalerConfig {
            enabled: false,
            max_replicas: 2,
            ..AutoscalerConfig::default()
        },
        cluster: ClusterConfig {
            nodes: 1,
            gpus_per_node: 2,
            pod_start_delay: Duration::from_millis(500),
            termination_grace: Duration::from_secs(1),
            pod_failure_rate: 0.0,
        },
        monitoring: MonitoringConfig {
            listen: String::new(),
            scrape_interval: Duration::from_secs(1),
            retention: Duration::from_secs(7200),
            tracing: false,
        },
        model_placement: ModelPlacementConfig::default(),
        engines: EnginesConfig::default(),
        observability: ObservabilityConfig::default(),
        rpc: Default::default(),
        federation: Default::default(),
        time_scale,
    }
}

/// The mixed-criticality workload for the priority ablation: a
/// saturating 8-row bulk stream plus a light 1-row latency-critical
/// stream on the SAME model. With `lanes` the streams are tagged
/// `bulk` / `critical`; without, both run `standard` — the
/// priority-blind baseline carrying identical traffic.
pub fn priority_workload(
    addr: &str,
    lanes: bool,
    clock: crate::util::clock::Clock,
) -> crate::workload::MixedPool {
    use crate::rpc::codec::Priority;
    let (bulk_class, critical_class) = if lanes {
        (Priority::Bulk, Priority::Critical)
    } else {
        (Priority::Standard, Priority::Standard)
    };
    let bulk = WorkloadSpec::new("particlenet", 8, vec![64, 7]).with_priority(bulk_class);
    let mut critical =
        WorkloadSpec::new("particlenet", 1, vec![64, 7]).with_priority(critical_class);
    critical.think_time = Duration::from_millis(10);
    crate::workload::MixedPool::new(
        addr,
        vec![
            crate::workload::MixEntry { spec: bulk, weight: 0.85 },
            crate::workload::MixEntry { spec: critical, weight: 0.15 },
        ],
        clock,
        0x9121,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_config_validates() {
        fig_config(4.0, None, Duration::from_secs(300)).validate().unwrap();
        fig_config(8.0, Some(10), Duration::from_secs(60)).validate().unwrap();
    }

    #[test]
    fn modelmesh_config_validates() {
        use crate::config::PlacementPolicy;
        for policy in [PlacementPolicy::Static, PlacementPolicy::Dynamic] {
            let cfg = modelmesh_config(8.0, policy);
            cfg.validate().unwrap();
            assert!(cfg.model_placement.mesh_enabled());
        }
    }

    #[test]
    fn short_mesh_run_holds_invariants() {
        use crate::config::PlacementPolicy;
        use crate::workload::Schedule;
        // Compressed dynamic run under a 90/10 skew: whatever the
        // controller did, the placement invariants must hold afterwards.
        let cfg = modelmesh_config(20.0, PlacementPolicy::Dynamic);
        let budget = cfg.model_placement.budget_bytes();
        let d = crate::deployment::Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(4, Duration::from_secs(30)));
        let pool = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
        let report = pool.run(&Schedule::constant(12, Duration::from_secs(40)));
        assert!(report.total_ok() > 0, "nothing served: {:?}", report.per_model);
        let router = d.router.as_ref().unwrap();
        // every model keeps >= min replicas, budget never violated, and
        // the hot model never ends up below the cold one
        assert!(router.replicas("particlenet") >= 1);
        assert!(router.replicas("icecube_cnn") >= 1);
        assert!(
            router.replicas("particlenet") >= router.replicas("icecube_cnn"),
            "hot model lost replicas under skewed load"
        );
        for inst in d.cluster.endpoints() {
            assert!(inst.memory_used() <= budget, "{} over memory budget", inst.id);
        }
        d.down();
    }

    #[test]
    fn warm_load_configs_validate() {
        use crate::config::BatchMode;
        for delay in [Duration::ZERO, Duration::from_secs(3)] {
            for mode in [BatchMode::Fifo, BatchMode::Affinity] {
                let cfg = warm_load_config(10.0, delay, mode);
                cfg.validate().unwrap();
                assert!(cfg.model_placement.mesh_enabled());
                assert_eq!(cfg.server.batch_mode, mode);
                assert_eq!(cfg.model_placement.load_delay, delay);
            }
        }
    }

    #[test]
    fn short_warm_load_run_holds_invariants() {
        use crate::config::BatchMode;
        use crate::workload::Schedule;
        // Compressed costed-affinity run with a mid-run demand flip (the
        // bench's shape): placement pays real load windows, and the
        // floors/budget must survive the migration.
        let cfg = warm_load_config(20.0, Duration::from_secs(3), BatchMode::Affinity);
        let budget = cfg.model_placement.budget_bytes();
        let d = crate::deployment::Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(4, Duration::from_secs(30)));
        let hot_phase = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
        let report_a = hot_phase.run(&Schedule::constant(12, Duration::from_secs(20)));
        let flipped = modelmesh_workload(&d.endpoint(), 0.1, d.clock.clone());
        let report_b = flipped.run(&Schedule::constant(12, Duration::from_secs(20)));
        assert!(report_a.total_ok() > 0, "phase A served nothing");
        assert!(report_b.total_ok() > 0, "phase B served nothing");
        let router = d.router.as_ref().unwrap();
        assert!(router.replicas("particlenet") >= 1);
        assert!(router.replicas("icecube_cnn") >= 1);
        for inst in d.cluster.endpoints() {
            assert!(inst.memory_used() <= budget, "{} over memory budget", inst.id);
        }
        d.down();
    }

    #[test]
    fn backend_configs_validate() {
        for cpu_pods in [0, 1, 2] {
            let cfg = backend_config(8.0, cpu_pods);
            cfg.validate().unwrap();
            assert_eq!(cfg.engines.cpu_replicas, cpu_pods);
            assert_eq!(cfg.server.replicas + cpu_pods, 4, "pod budget not equal");
            assert!(cfg.model_placement.mesh_enabled());
            assert_eq!(cfg.server.models[1].backends, vec!["onnx-sim".to_string()]);
        }
    }

    #[test]
    fn short_backend_run_holds_compat_invariant() {
        use crate::workload::Schedule;
        // Compressed mixed-fleet run: the CPU-only model must be served
        // (on CPU pods exclusively), the hot model must keep its GPU
        // replicas, and at least one fallback must have been counted
        // (the hot model boot-placed onto a CPU pod via onnx-sim).
        let cfg = backend_config(20.0, 1);
        let d = crate::deployment::Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(4, Duration::from_secs(30)));
        let pool = backend_workload(&d.endpoint(), d.clock.clone());
        let report = pool.run(&Schedule::constant(10, Duration::from_secs(25)));
        let cold = &report.per_model["icecube_cnn"];
        assert!(cold.ok > 0, "CPU-only model never served: {:?}", report.per_model);
        assert!(report.per_model["particlenet"].ok > 0, "hot model never served");
        let router = d.router.as_ref().unwrap();
        // Every replica of the CPU-only model advertises onnx-sim and
        // serves the model on it — never a PJRT-only pod.
        let replicas = router.endpoints_for("icecube_cnn");
        assert!(!replicas.is_empty());
        for inst in replicas {
            assert!(
                inst.backend_names().contains(&"onnx-sim".to_string()),
                "{} hosts the CPU-only model without onnx-sim",
                inst.id
            );
            assert_eq!(inst.backend_for_model("icecube_cnn").as_deref(), Some("onnx-sim"));
        }
        assert!(
            d.store.sum_latest_prefix("backend_fallback_total") >= 1.0,
            "no backend fallback recorded on the mixed fleet"
        );
        d.down();
    }

    #[test]
    fn priority_config_validates() {
        let cfg = priority_config(8.0, "prio-test");
        cfg.validate().unwrap();
        assert_eq!(cfg.server.replicas, 2);
        assert!(!cfg.autoscaler.enabled);
    }

    #[test]
    fn short_priority_run_protects_critical() {
        use crate::workload::Schedule;
        // Compressed priority-lanes run under bulk saturation: the
        // critical stream must survive largely unshed (shed-from-bulk
        // protects it at admission) and the lanes must actually preempt.
        let cfg = priority_config(10.0, "prio-short");
        let d = crate::deployment::Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(30)));
        let pool = priority_workload(&d.endpoint(), true, d.clock.clone());
        let report = pool.run(&Schedule::constant(10, Duration::from_secs(20)));
        let bulk = &report.per_entry[0];
        let crit = &report.per_entry[1];
        assert!(crit.ok > 0, "critical stream never served");
        assert!(bulk.ok > 0, "bulk stream starved entirely");
        assert!(
            crit.shed <= crit.ok / 10,
            "critical shed {} times against {} served — bulk was not shed first",
            crit.shed,
            crit.ok
        );
        assert!(
            d.store.sum_latest_prefix("batch_preemptions_total") >= 1.0,
            "no preemptions recorded under mixed-priority saturation"
        );
        d.down();
    }

    #[test]
    fn per_model_autoscale_configs_validate() {
        for arm in [false, true] {
            let cfg = per_model_autoscale_config(8.0, arm);
            cfg.validate().unwrap();
            assert_eq!(cfg.autoscaler.per_model.enabled, arm);
            assert!(cfg.model_placement.mesh_enabled());
        }
    }

    #[test]
    fn short_per_model_autoscale_run() {
        use crate::workload::Schedule;
        // Compressed per-model arm under a 90/10 skew: the hot model must
        // gain dedicated pods while the fleet respects the shared budget.
        let cfg = per_model_autoscale_config(20.0, true);
        let budget = cfg.autoscaler.max_replicas;
        let floor = cfg.autoscaler.per_model.min_replicas;
        let d = crate::deployment::Deployment::up(cfg).unwrap();
        assert!(d.wait_ready(2, Duration::from_secs(30)));
        let pool = modelmesh_workload(&d.endpoint(), 0.9, d.clock.clone());
        let report = pool.run(&Schedule::constant(12, Duration::from_secs(30)));
        assert!(report.total_ok() > 0, "nothing served: {:?}", report.per_model);
        let hot = d.cluster.desired_for("particlenet");
        let cold = d.cluster.desired_for("icecube_cnn");
        assert!(hot > 1, "hot model never gained a dedicated pod (target {hot})");
        assert!(hot >= cold, "hot target {hot} below cold target {cold}");
        assert!(cold >= floor);
        assert!(hot + cold <= budget, "targets {hot}+{cold} exceed budget {budget}");
        d.down();
    }

    #[test]
    fn short_dynamic_run_scales_up() {
        // Compressed Fig. 2: 30x time scale, 60-second clock phases. The
        // 10-client phase must trigger at least one scale-up.
        let phase = Duration::from_secs(90);
        let cfg = fig_config(30.0, None, phase);
        let schedule = Schedule::new()
            .phase(1, Duration::from_secs(30))
            .phase(10, phase);
        let result =
            run_deployment(cfg, fig_workload(), &schedule, Duration::from_secs(5)).unwrap();
        assert!(
            result.peak_servers >= 2,
            "no scale-up observed (peak {})",
            result.peak_servers
        );
        assert!(result.report.total_ok > 0);
    }
}
