//! Multi-backend engine layer — pluggable inference runtimes.
//!
//! The paper's core portability claim is that the same serving
//! infrastructure runs models on different *backends* (Triton's
//! TensorRT / ONNX Runtime / PyTorch backends) over different
//! *coprocessor types* (GPUs of several vendors, or plain CPUs). This
//! module is that seam in the reproduction:
//!
//! * [`Backend`] — the runtime contract: a name, capability tags (which
//!   accelerator classes it can run on), per-backend load/memory cost
//!   multipliers, and batch execution.
//! * [`PjrtBackend`] — the existing PJRT runtime wrapped as a backend:
//!   executes compiled AOT artifacts (or the calibrated service-time
//!   model under `execution: simulated`). GPU-class pods only.
//! * [`OnnxSimBackend`] — a deterministic simulated second runtime (the
//!   ONNX-Runtime-on-CPU analogue): CPU-capable, usable without the
//!   `pjrt` cargo feature, with its own latency slowdown and load/memory
//!   cost multipliers (`engines.*` config).
//! * [`BackendRegistry`] — the deployment's backend set, and the mapping
//!   from a pod's [`AcceleratorClass`] to the backends it advertises.
//! * [`EngineCatalog`] — per-model backend preference lists (from
//!   `server.models[].backends`, defaulting to `engines.default_backend`
//!   first), and the selection rule instances use when loading a model:
//!   first preferred backend the instance supports; any later pick is a
//!   **fallback** (counted in `backend_fallback_total`).
//!
//! The rest of the control plane is backend-aware on top of this layer:
//! pods advertise a backend set derived from their accelerator class,
//! [`Instance`](crate::server::Instance) serving sets record which
//! backend serves each model (charging per-backend load delays and
//! memory), and [`PlacementCore`](crate::modelmesh::PlacementCore) only
//! places a model on instances with a compatible backend — so a model
//! configured `backends: [onnx-sim]` can never land on, be routed to,
//! or be executed by a PJRT-only instance.

pub mod backend;
pub mod catalog;

pub use backend::{Backend, ExecCtx, OnnxSimBackend, PjrtBackend};
pub use catalog::{BackendRegistry, EngineCatalog};

use anyhow::{bail, Result};

/// Rust type names of every [`Backend`] implementation — the doc-sync
/// gate (`rust/tests/docs_sync.rs`) requires each to appear in
/// `docs/ARCHITECTURE.md`, so a new backend cannot land undocumented.
pub const BACKEND_IMPLS: &[&str] = &["PjrtBackend", "OnnxSimBackend"];

/// Coprocessor class a pod's node provides. Boot profiles carry one:
/// the pod's instance advertises exactly the backends whose capability
/// tags include this class, so a `cpu` pod never claims it can run PJRT
/// engines and a heterogeneous fleet partitions cleanly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum AcceleratorClass {
    /// GPU-slot pod (the classic Triton server shape).
    #[default]
    Gpu,
    /// CPU-only pod (`engines.cpu_replicas`): no GPU engine can run
    /// here, only CPU-capable backends.
    Cpu,
}

impl AcceleratorClass {
    /// Canonical capability-tag / config name.
    pub fn name(&self) -> &'static str {
        match self {
            AcceleratorClass::Gpu => "gpu",
            AcceleratorClass::Cpu => "cpu",
        }
    }

    /// Parse a capability-tag name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gpu" => AcceleratorClass::Gpu,
            "cpu" => AcceleratorClass::Cpu,
            other => bail!("unknown accelerator class '{other}' (expected gpu or cpu)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accelerator_class_roundtrips() {
        for c in [AcceleratorClass::Gpu, AcceleratorClass::Cpu] {
            assert_eq!(AcceleratorClass::parse(c.name()).unwrap(), c);
        }
        assert!(AcceleratorClass::parse("tpu").is_err());
        assert_eq!(AcceleratorClass::default(), AcceleratorClass::Gpu);
    }

    #[test]
    fn backend_impls_cover_known_backends() {
        // One Rust impl per wire-level backend name, and vice versa.
        assert_eq!(BACKEND_IMPLS.len(), crate::config::schema::BACKEND_NAMES.len());
    }
}
