//! The [`Backend`] trait and its two implementations.
//!
//! A backend is the thing that actually runs a batch: the executor
//! thread in [`Instance`](crate::server::Instance) pops a same-model
//! batch, looks up the backend its serving set recorded for that model,
//! and hands it an [`ExecCtx`]. Everything above the trait (batching,
//! routing, placement, metrics) is backend-agnostic.

use std::time::Duration;

use anyhow::Result;

use crate::config::{ExecutionMode, ServiceModelConfig};
use crate::runtime::Tensor;
use crate::server::repository::ModelEntry;
use crate::util::clock::Clock;

/// Everything a backend needs to run one same-model batch.
pub struct ExecCtx<'a> {
    /// The model being served (shapes, compiled engines, batch sizes).
    pub entry: &'a ModelEntry,
    /// One input tensor per request, in batch order.
    pub inputs: &'a [&'a Tensor],
    /// Total rows across `inputs`.
    pub total_rows: usize,
    /// The deployment's execution mode (`real` runs compiled engines
    /// where the backend has them; `simulated` sleeps calibrated
    /// service times).
    pub mode: ExecutionMode,
    /// The model's calibrated GPU service-time model; backends apply
    /// their own latency multiplier on top.
    pub service: ServiceModelConfig,
    /// Deployment clock (time dilation applies to simulated service).
    pub clock: &'a Clock,
}

/// One pluggable inference runtime.
///
/// Implementations must be cheap to share (`Arc<dyn Backend>` is cloned
/// into every serving-set entry) and thread-safe: a fleet of executor
/// threads calls [`Backend::execute`] concurrently. `RefUnwindSafe` is
/// required so types embedding backends (instances, registries) stay
/// usable across the property-test harness's `catch_unwind`.
pub trait Backend:
    Send + Sync + std::fmt::Debug + std::panic::RefUnwindSafe + std::panic::UnwindSafe
{
    /// Stable wire/config/metrics name (one of
    /// [`config::schema::BACKEND_NAMES`](crate::config::schema::BACKEND_NAMES)).
    fn name(&self) -> &'static str;

    /// Capability tags: the [`AcceleratorClass`](super::AcceleratorClass)
    /// names this backend can run on. A pod advertises exactly the
    /// backends whose tags include its class.
    fn capabilities(&self) -> &'static [&'static str];

    /// Multiplier applied to a model's warm-load delay when this backend
    /// serves it (engine build vs session init cost).
    fn load_multiplier(&self) -> f64 {
        1.0
    }

    /// Multiplier applied to a model's simulated memory footprint when
    /// this backend serves it. Kept at or below 1.0 so the placement
    /// planner (which budgets with the unscaled footprint) stays
    /// conservative — see `DeploymentConfig::validate`.
    fn memory_multiplier(&self) -> f64 {
        1.0
    }

    /// Run one same-model batch; returns one output tensor per input,
    /// in order.
    fn execute(&self, ctx: &ExecCtx<'_>) -> Result<Vec<Tensor>>;
}

/// Chunked service time of a batch under the calibrated linear model:
/// the batch is split by the model's largest engine batch, and each
/// chunk is charged at the smallest compiled batch size that fits it
/// (exactly how the real execution path pads) — shared by both
/// simulated execution paths so the two backends differ only by their
/// latency multiplier.
fn chunked_service_secs(entry: &ModelEntry, total_rows: usize, service: ServiceModelConfig) -> f64 {
    let max_engine = entry.max_batch();
    let mut secs = 0.0f64;
    let mut done = 0usize;
    while done < total_rows {
        let n = (total_rows - done).min(max_engine);
        let padded = entry
            .batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(max_engine);
        secs += service.service_secs(padded);
        done += n;
    }
    secs
}

/// Sleep the (multiplied) service time and return zeroed outputs of the
/// correct per-request shapes — the deterministic simulated execution
/// path both backends share.
fn execute_simulated(ctx: &ExecCtx<'_>, latency_multiplier: f64) -> Result<Vec<Tensor>> {
    let secs = chunked_service_secs(ctx.entry, ctx.total_rows, ctx.service) * latency_multiplier;
    ctx.clock.sleep(Duration::from_secs_f64(secs));
    Ok(ctx
        .inputs
        .iter()
        .map(|t| Tensor::zeros(vec![t.batch(), ctx.entry.output_dim]))
        .collect())
}

/// The PJRT runtime as a backend: compiled AOT artifacts on GPU-class
/// pods. Under `execution: simulated` it sleeps the model's calibrated
/// service time instead (the pre-existing simulated-GPU path, unscaled).
#[derive(Clone, Copy, Debug, Default)]
pub struct PjrtBackend;

impl PjrtBackend {
    /// The canonical PJRT backend.
    pub fn new() -> Self {
        PjrtBackend
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capabilities(&self) -> &'static [&'static str] {
        &["gpu"]
    }

    fn execute(&self, ctx: &ExecCtx<'_>) -> Result<Vec<Tensor>> {
        if ctx.mode == ExecutionMode::Simulated {
            return execute_simulated(ctx, 1.0);
        }
        let entry = ctx.entry;
        let max_engine = entry.max_batch();
        let engines = entry.engines.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "model '{}' was loaded metadata-only; real execution unavailable",
                entry.name
            )
        })?;

        // Fast path — a single request that fits one engine call (the
        // common case at low batch pressure): one pad, one execute, one
        // slice, instead of the flatten/chunk/regroup pipeline below
        // (saves 4 full tensor copies per request).
        if ctx.inputs.len() == 1 && ctx.total_rows <= max_engine {
            let engine = engines.engine_for(ctx.total_rows);
            let eb = engine.batch_size();
            let out = if ctx.total_rows == eb {
                engine.execute(ctx.inputs[0])?
            } else {
                let padded = Tensor::stack_padded(std::slice::from_ref(ctx.inputs[0]), eb)?;
                engine.execute(&padded)?.slice_rows(0, ctx.total_rows)?
            };
            return Ok(vec![out]);
        }

        let inputs: Vec<Tensor> = ctx.inputs.iter().map(|t| (*t).clone()).collect();

        // Flatten all rows into one tensor, then chunk.
        let flat = Tensor::stack_padded(&inputs, ctx.total_rows)?;
        let mut out_rows: Vec<Tensor> = Vec::new();
        let mut done = 0usize;
        while done < ctx.total_rows {
            let n = (ctx.total_rows - done).min(max_engine);
            let chunk = flat.slice_rows(done, n)?;
            let engine = engines.engine_for(n);
            let eb = engine.batch_size();
            let padded = Tensor::stack_padded(&[chunk], eb)?;
            let out = engine.execute(&padded)?;
            out_rows.push(out.slice_rows(0, n)?);
            done += n;
        }
        let all_out = Tensor::stack_padded(&out_rows, ctx.total_rows)?;

        // Split back per request.
        let mut outputs = Vec::with_capacity(ctx.inputs.len());
        let mut offset = 0usize;
        for t in ctx.inputs {
            let r = t.batch();
            outputs.push(all_out.slice_rows(offset, r)?);
            offset += r;
        }
        Ok(outputs)
    }
}

/// Deterministic simulated ONNX-Runtime-style backend: CPU-capable,
/// needs no compiled engines (and no `pjrt` cargo feature), and prices
/// everything through its own cost model — a latency slowdown against
/// the model's calibrated GPU service model, plus load/memory
/// multipliers. Identical inputs always produce identical (zeroed)
/// outputs and identical simulated timings.
#[derive(Clone, Copy, Debug)]
pub struct OnnxSimBackend {
    /// Latency multiplier vs the model's GPU service model
    /// (`engines.onnx_slowdown`).
    pub slowdown: f64,
    /// Warm-load delay multiplier (`engines.onnx_load_multiplier`):
    /// session init is cheaper than engine compilation.
    pub load_multiplier: f64,
    /// Memory-footprint multiplier (`engines.onnx_memory_multiplier`),
    /// validated to stay in (0, 1].
    pub memory_multiplier: f64,
}

impl Default for OnnxSimBackend {
    fn default() -> Self {
        OnnxSimBackend { slowdown: 4.0, load_multiplier: 0.5, memory_multiplier: 1.0 }
    }
}

impl Backend for OnnxSimBackend {
    fn name(&self) -> &'static str {
        "onnx-sim"
    }

    fn capabilities(&self) -> &'static [&'static str] {
        &["cpu"]
    }

    fn load_multiplier(&self) -> f64 {
        self.load_multiplier
    }

    fn memory_multiplier(&self) -> f64 {
        self.memory_multiplier
    }

    fn execute(&self, ctx: &ExecCtx<'_>) -> Result<Vec<Tensor>> {
        // Always the simulated path: this backend models a second
        // runtime, it never touches PJRT engines — which is what makes
        // it usable on CPU pods and without the `pjrt` feature.
        execute_simulated(ctx, self.slowdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::ModelRepository;
    use std::sync::Arc;
    use std::time::Instant;

    fn entry() -> Arc<ModelEntry> {
        let repo = ModelRepository::load_metadata(
            std::path::Path::new("artifacts"),
            &["icecube_cnn".into()],
        )
        .unwrap();
        repo.get("icecube_cnn").unwrap()
    }

    fn ctx<'a>(
        entry: &'a ModelEntry,
        inputs: &'a [&'a Tensor],
        total_rows: usize,
        clock: &'a Clock,
    ) -> ExecCtx<'a> {
        ExecCtx {
            entry,
            inputs,
            total_rows,
            mode: ExecutionMode::Simulated,
            service: ServiceModelConfig {
                base: Duration::from_millis(10),
                per_row: Duration::from_millis(1),
            },
            clock,
        }
    }

    #[test]
    fn chunked_service_pads_to_engine_batches() {
        let e = entry(); // batch sizes 1,2,4,8,16
        let sm = ServiceModelConfig {
            base: Duration::from_millis(10),
            per_row: Duration::from_millis(1),
        };
        // 3 rows pad to the 4-engine: 10 + 4 = 14 ms
        assert!((chunked_service_secs(&e, 3, sm) - 0.014).abs() < 1e-9);
        // 20 rows chunk to 16 + 4: (10 + 16) + (10 + 4) = 40 ms
        assert!((chunked_service_secs(&e, 20, sm) - 0.040).abs() < 1e-9);
    }

    #[test]
    fn pjrt_simulated_sleeps_base_service() {
        let e = entry();
        let clock = Clock::real();
        let input = Tensor::zeros(vec![2, 16, 16, 3]);
        let inputs = [&input];
        let t0 = Instant::now();
        let out = PjrtBackend::new().execute(&ctx(&e, &inputs, 2, &clock)).unwrap();
        // padded to engine batch 2: 10 + 2 = 12 ms
        assert!(t0.elapsed() >= Duration::from_millis(11));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[2, 3]);
        assert!(out[0].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn onnx_sim_applies_slowdown_and_stays_deterministic() {
        let e = entry();
        let clock = Clock::real();
        let a = Tensor::zeros(vec![1, 16, 16, 3]);
        let b = Tensor::zeros(vec![2, 16, 16, 3]);
        let inputs = [&a, &b];
        let backend = OnnxSimBackend { slowdown: 3.0, ..Default::default() };
        let t0 = Instant::now();
        let out = backend.execute(&ctx(&e, &inputs, 3, &clock)).unwrap();
        // padded to engine batch 4: (10 + 4) * 3 = 42 ms
        assert!(t0.elapsed() >= Duration::from_millis(40));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].shape(), &[1, 3]);
        assert_eq!(out[1].shape(), &[2, 3]);
        let again = backend.execute(&ctx(&e, &inputs, 3, &clock)).unwrap();
        assert_eq!(out[1], again[1], "onnx-sim output not deterministic");
    }

    #[test]
    fn pjrt_real_without_engines_errors() {
        let e = entry(); // metadata-only: no compiled engines
        let clock = Clock::real();
        let input = Tensor::zeros(vec![1, 16, 16, 3]);
        let inputs = [&input];
        let mut c = ctx(&e, &inputs, 1, &clock);
        c.mode = ExecutionMode::Real;
        let err = PjrtBackend::new().execute(&c).unwrap_err();
        assert!(err.to_string().contains("metadata-only"), "{err}");
    }

    #[test]
    fn capability_tags_partition_classes() {
        use crate::engine::AcceleratorClass;
        let pjrt = PjrtBackend::new();
        let onnx = OnnxSimBackend::default();
        assert!(pjrt.capabilities().contains(&AcceleratorClass::Gpu.name()));
        assert!(!pjrt.capabilities().contains(&AcceleratorClass::Cpu.name()));
        assert!(onnx.capabilities().contains(&AcceleratorClass::Cpu.name()));
        assert!(!onnx.capabilities().contains(&AcceleratorClass::Gpu.name()));
        assert_eq!(pjrt.load_multiplier(), 1.0);
        assert!(onnx.load_multiplier() < 1.0);
    }
}
