//! Backend registry + per-model engine catalog.
//!
//! The [`BackendRegistry`] is the deployment's backend set (built once
//! from the `engines.*` config); the [`EngineCatalog`] maps each served
//! model to the backend variants that can serve it, in preference
//! order. Together they answer the two questions the control plane
//! asks: *which backends does this pod advertise* (by accelerator
//! class) and *which backend should serve model M here* (first
//! preference the pod supports — anything later is a fallback).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::schema::BACKEND_NAMES;
use crate::config::{EnginesConfig, ModelConfig};

use super::{AcceleratorClass, Backend, OnnxSimBackend, PjrtBackend};

/// The deployment's backend set.
pub struct BackendRegistry {
    backends: Vec<Arc<dyn Backend>>,
}

impl BackendRegistry {
    /// Registry with every known backend, parameterized by the
    /// `engines.*` config (the onnx-sim cost model).
    pub fn from_config(cfg: &EnginesConfig) -> Self {
        BackendRegistry {
            backends: vec![
                Arc::new(PjrtBackend::new()),
                Arc::new(OnnxSimBackend {
                    slowdown: cfg.onnx_slowdown,
                    load_multiplier: cfg.onnx_load_multiplier,
                    memory_multiplier: cfg.onnx_memory_multiplier,
                }),
            ],
        }
    }

    /// Look up a backend by wire name.
    pub fn get(&self, name: &str) -> Option<Arc<dyn Backend>> {
        self.backends.iter().find(|b| b.name() == name).cloned()
    }

    /// Every registered backend.
    pub fn backends(&self) -> &[Arc<dyn Backend>] {
        &self.backends
    }

    /// The backend set a pod of `class` advertises: every backend whose
    /// capability tags include the class. Non-empty for both known
    /// classes (onnx-sim covers `cpu`, pjrt covers `gpu`).
    pub fn for_class(&self, class: AcceleratorClass) -> Vec<Arc<dyn Backend>> {
        self.backends
            .iter()
            .filter(|b| b.capabilities().contains(&class.name()))
            .cloned()
            .collect()
    }
}

impl Default for BackendRegistry {
    fn default() -> Self {
        Self::from_config(&EnginesConfig::default())
    }
}

/// Per-model backend preference lists.
///
/// A model with an explicit `server.models[].backends` list is served by
/// exactly those backends, in that order; a model without one gets the
/// default preference (`engines.default_backend` first, then every
/// other known backend). Models absent from the catalog entirely (unit
/// tests, hot-loaded models) also use the default preference.
#[derive(Clone, Debug)]
pub struct EngineCatalog {
    prefs: BTreeMap<String, Vec<String>>,
    default_prefs: Vec<String>,
}

impl EngineCatalog {
    /// Resolve the catalog for a served model set.
    pub fn resolve(models: &[ModelConfig], engines: &EnginesConfig) -> Self {
        let default_prefs = Self::default_prefs_for(&engines.default_backend);
        let prefs = models
            .iter()
            .map(|m| {
                let p = if m.backends.is_empty() {
                    default_prefs.clone()
                } else {
                    m.backends.clone()
                };
                (m.name.clone(), p)
            })
            .collect();
        EngineCatalog { prefs, default_prefs }
    }

    fn default_prefs_for(default_backend: &str) -> Vec<String> {
        let mut prefs = vec![default_backend.to_string()];
        prefs.extend(
            BACKEND_NAMES
                .iter()
                .filter(|b| **b != default_backend)
                .map(|b| b.to_string()),
        );
        prefs
    }

    /// Has no model been cataloged? An empty catalog answers every
    /// lookup with the default preference — consumers holding the model
    /// list (e.g. [`Instance`](crate::server::Instance) construction)
    /// use this to resolve a real catalog instead, so per-model
    /// `backends` lists are honored even when no catalog was wired in.
    pub fn is_empty(&self) -> bool {
        self.prefs.is_empty()
    }

    /// Preference-ordered backend names for one model.
    pub fn backends_for(&self, model: &str) -> &[String] {
        if let Some(p) = self.prefs.get(model) {
            return p;
        }
        // A versioned name not cataloged explicitly inherits its base
        // model's preferences (versions share weights and hence backend
        // constraints) before the catalog-wide default applies.
        let (base, version) = crate::server::split_version(model);
        if version.is_some() {
            if let Some(p) = self.prefs.get(base) {
                return p;
            }
        }
        &self.default_prefs
    }

    /// May `backend` serve `model` at all?
    pub fn compatible(&self, model: &str, backend: &str) -> bool {
        self.backends_for(model).iter().any(|b| b == backend)
    }

    /// The backend that serves `model` on an instance advertising
    /// `available`: the first preference present in the set, with its
    /// preference rank (0 = preferred; anything greater is a fallback).
    /// `None` when no available backend is compatible — the instance
    /// cannot host the model.
    pub fn select(
        &self,
        model: &str,
        available: &[Arc<dyn Backend>],
    ) -> Option<(Arc<dyn Backend>, usize)> {
        self.backends_for(model)
            .iter()
            .enumerate()
            .find_map(|(rank, name)| {
                available
                    .iter()
                    .find(|b| b.name() == name.as_str())
                    .map(|b| (Arc::clone(b), rank))
            })
    }

    /// The compatibility map the placement planner consumes:
    /// model → preference-ordered backend names, for every cataloged
    /// model.
    pub fn compat_map(&self) -> BTreeMap<String, Vec<String>> {
        self.prefs.clone()
    }
}

impl Default for EngineCatalog {
    fn default() -> Self {
        EngineCatalog {
            prefs: BTreeMap::new(),
            default_prefs: Self::default_prefs_for(BACKEND_NAMES[0]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn model(name: &str, backends: &[&str]) -> ModelConfig {
        ModelConfig {
            name: name.into(),
            backends: backends.iter().map(|s| s.to_string()).collect(),
            ..ModelConfig::default()
        }
    }

    #[test]
    fn registry_partitions_by_class() {
        let reg = BackendRegistry::default();
        let gpu: Vec<&str> =
            reg.for_class(AcceleratorClass::Gpu).iter().map(|b| b.name()).collect();
        let cpu: Vec<&str> =
            reg.for_class(AcceleratorClass::Cpu).iter().map(|b| b.name()).collect();
        assert_eq!(gpu, vec!["pjrt"]);
        assert_eq!(cpu, vec!["onnx-sim"]);
        assert!(reg.get("pjrt").is_some());
        assert!(reg.get("onnx-sim").is_some());
        assert!(reg.get("tensorrt").is_none());
    }

    #[test]
    fn registry_applies_engines_config() {
        let cfg = EnginesConfig {
            onnx_slowdown: 2.5,
            onnx_load_multiplier: 0.25,
            onnx_memory_multiplier: 0.75,
            ..EnginesConfig::default()
        };
        let reg = BackendRegistry::from_config(&cfg);
        let onnx = reg.get("onnx-sim").unwrap();
        assert_eq!(onnx.load_multiplier(), 0.25);
        assert_eq!(onnx.memory_multiplier(), 0.75);
    }

    #[test]
    fn catalog_resolves_defaults_and_overrides() {
        let engines = EnginesConfig::default(); // default_backend: pjrt
        let models = vec![model("free", &[]), model("cpu_only", &["onnx-sim"])];
        let cat = EngineCatalog::resolve(&models, &engines);
        assert_eq!(cat.backends_for("free"), ["pjrt", "onnx-sim"]);
        assert_eq!(cat.backends_for("cpu_only"), ["onnx-sim"]);
        // uncataloged models fall back to the default preference
        assert_eq!(cat.backends_for("unknown"), ["pjrt", "onnx-sim"]);
        assert!(cat.compatible("free", "onnx-sim"));
        assert!(!cat.compatible("cpu_only", "pjrt"));
    }

    #[test]
    fn default_backend_reorders_preference() {
        let engines = EnginesConfig {
            default_backend: "onnx-sim".into(),
            ..EnginesConfig::default()
        };
        let cat = EngineCatalog::resolve(&[model("m", &[])], &engines);
        assert_eq!(cat.backends_for("m"), ["onnx-sim", "pjrt"]);
    }

    #[test]
    fn select_prefers_then_falls_back_then_refuses() {
        let reg = BackendRegistry::default();
        let engines = EnginesConfig::default();
        let cat = EngineCatalog::resolve(
            &[model("free", &[]), model("cpu_only", &["onnx-sim"])],
            &engines,
        );
        let gpu = reg.for_class(AcceleratorClass::Gpu);
        let cpu = reg.for_class(AcceleratorClass::Cpu);
        // preferred backend available: rank 0
        let (b, rank) = cat.select("free", &gpu).unwrap();
        assert_eq!((b.name(), rank), ("pjrt", 0));
        // only the second preference available: a fallback
        let (b, rank) = cat.select("free", &cpu).unwrap();
        assert_eq!((b.name(), rank), ("onnx-sim", 1));
        // no compatible backend at all
        assert!(cat.select("cpu_only", &gpu).is_none());
        // selection never leaves the preference list
        for avail in [&gpu, &cpu] {
            if let Some((b, _)) = cat.select("cpu_only", avail) {
                assert!(cat.compatible("cpu_only", b.name()));
            }
        }
    }
}
