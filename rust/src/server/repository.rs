//! Model repository: the directory layout `python/compile/aot.py` emits,
//! loaded and compiled through the PJRT runtime.
//!
//! Layout (a Triton model repository, one version per directory):
//!
//! ```text
//!     artifacts/
//!       particlenet/
//!         config.yaml
//!         model.b1.hlo.txt ... model.b16.hlo.txt
//!         golden.b1.txt ...
//! ```
//!
//! All instances share one `ModelRepository` (engines are `Arc`ed and PJRT
//! executables are thread-safe); what is *per instance* is the queue and
//! the serialized executor, not the compiled code — same as Triton pods
//! sharing a model store.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::yaml;
use crate::runtime::{EngineSet, PjrtRuntime};

/// Canonical serving name of one model version: `base@vN`.
pub fn versioned_name(base: &str, version: u32) -> String {
    format!("{base}@v{version}")
}

/// Split a serving name into (base, version). `"pn@v2"` → `("pn", Some(2))`;
/// a name without a `@vN` suffix is its own base.
pub fn split_version(name: &str) -> (&str, Option<u32>) {
    if let Some((base, v)) = name.rsplit_once("@v") {
        if !base.is_empty() && !v.is_empty() && v.bytes().all(|b| b.is_ascii_digit()) {
            if let Ok(n) = v.parse::<u32>() {
                return (base, Some(n));
            }
        }
    }
    (name, None)
}

/// The registered versions of one base name, with the incumbent (the
/// version unversioned requests resolve to by default).
#[derive(Clone, Debug)]
struct VersionSet {
    versions: BTreeSet<u32>,
    incumbent: u32,
}

/// Parsed per-model metadata + compiled engines.
pub struct ModelEntry {
    pub name: String,
    /// Per-sample input shape (without batch dim).
    pub input_shape: Vec<usize>,
    /// Output width (logits).
    pub output_dim: usize,
    /// Declared parameter count (informational).
    pub parameters: u64,
    /// Batch-size variants declared in `config.yaml` (cross-checked
    /// against compiled artifacts when engines are loaded).
    pub batch_sizes: Vec<usize>,
    /// Compiled batch-size variants. `None` when the repository was
    /// loaded metadata-only (`ExecutionMode::Simulated` deployments
    /// never execute, so compiling every artifact would only slow
    /// boot — exactly like a Triton pod that never loads a model it
    /// does not serve).
    pub engines: Option<EngineSet>,
}

impl ModelEntry {
    /// Largest compiled/declared batch.
    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().expect("validated non-empty")
    }

    /// Simulated GPU-memory footprint of one loaded copy of this model:
    /// f32 weights, so four bytes per declared parameter. Drives the
    /// modelmesh placement controller's per-instance memory budget.
    pub fn memory_bytes(&self) -> u64 {
        self.parameters.max(1) * 4
    }

    /// Validate a request tensor shape against the model contract:
    /// (b, *input_shape) with b >= 1.
    pub fn validate_input(&self, shape: &[usize]) -> Result<()> {
        if shape.len() != self.input_shape.len() + 1 {
            bail!(
                "model '{}' expects rank {} input (batch + {:?}), got {:?}",
                self.name,
                self.input_shape.len() + 1,
                self.input_shape,
                shape
            );
        }
        if shape[0] == 0 {
            bail!("empty batch");
        }
        if shape[1..] != self.input_shape[..] {
            bail!(
                "model '{}' expects per-sample shape {:?}, got {:?}",
                self.name,
                self.input_shape,
                &shape[1..]
            );
        }
        Ok(())
    }
}

/// All models the deployment serves.
///
/// The model map is behind an `RwLock` so models can be loaded/unloaded
/// at runtime (Triton's explicit model-control mode): `get` on the hot
/// path takes a read lock; `load_model_dynamic`/`unload` mutate.
pub struct ModelRepository {
    root: PathBuf,
    models: std::sync::RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Per-base-name version sets (`base@vN` lifecycle bookkeeping).
    versions: std::sync::RwLock<BTreeMap<String, VersionSet>>,
}

impl std::fmt::Debug for ModelRepository {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRepository")
            .field("root", &self.root)
            .field("models", &self.names())
            .finish()
    }
}

#[cfg(test)]
mod hot_load_tests {
    use super::*;

    #[test]
    fn hot_load_and_unload() {
        let repo = ModelRepository::load_metadata(
            Path::new("artifacts"),
            &["icecube_cnn".into()],
        )
        .unwrap();
        assert!(repo.get("particlenet").is_none());
        // hot-load a second model (metadata-only)
        let entry = repo.load_model_dynamic(None, "particlenet").unwrap();
        assert_eq!(entry.name, "particlenet");
        assert!(repo.get("particlenet").is_some());
        assert_eq!(repo.names().len(), 2);
        // in-flight Arc survives unload
        let held = repo.get("particlenet").unwrap();
        assert!(repo.unload("particlenet"));
        assert!(repo.get("particlenet").is_none());
        assert_eq!(held.max_batch(), 16);
        // unload of a missing model reports false
        assert!(!repo.unload("particlenet"));
    }

    #[test]
    fn hot_load_unknown_model_errors() {
        let repo = ModelRepository::load_metadata(
            Path::new("artifacts"),
            &["icecube_cnn".into()],
        )
        .unwrap();
        assert!(repo.load_model_dynamic(None, "not_a_model").is_err());
    }
}

impl ModelRepository {
    /// Load `names` from the repository at `root`, compiling all artifacts.
    pub fn load(runtime: &PjrtRuntime, root: &Path, names: &[String]) -> Result<Self> {
        Self::load_inner(Some(runtime), root, names)
    }

    /// Load metadata only (no PJRT compilation) — for simulated-execution
    /// deployments and config validation tooling.
    pub fn load_metadata(root: &Path, names: &[String]) -> Result<Self> {
        Self::load_inner(None, root, names)
    }

    fn load_inner(runtime: Option<&PjrtRuntime>, root: &Path, names: &[String]) -> Result<Self> {
        let mut models = BTreeMap::new();
        for name in names {
            let entry = Self::load_model(runtime, root, name)
                .with_context(|| format!("loading model '{name}'"))?;
            models.insert(name.clone(), Arc::new(entry));
        }
        if models.is_empty() {
            bail!("model repository would be empty");
        }
        Ok(ModelRepository {
            root: root.to_path_buf(),
            models: std::sync::RwLock::new(models),
            versions: std::sync::RwLock::new(BTreeMap::new()),
        })
    }

    /// Register version `N` of an already-loaded base model, serving it
    /// under the `base@vN` name. Every version shares the base entry's
    /// compiled engines and metadata (the Triton version-directory
    /// analogue: one repository entry, several numbered versions of it);
    /// behavioral differences between versions are modeled by the
    /// per-version service-model config. The first registered version
    /// becomes the incumbent.
    pub fn register_version(&self, base: &str, version: u32) -> Result<Arc<ModelEntry>> {
        let entry = self
            .get(base)
            .with_context(|| format!("registering version of unloaded model '{base}'"))?;
        let name = versioned_name(base, version);
        self.models
            .write()
            .unwrap()
            .insert(name, Arc::clone(&entry));
        let mut versions = self.versions.write().unwrap();
        let set = versions.entry(base.to_string()).or_insert(VersionSet {
            versions: BTreeSet::new(),
            incumbent: version,
        });
        set.versions.insert(version);
        Ok(entry)
    }

    /// Mark `version` as the incumbent of `base`. Returns false when the
    /// version was never registered (the incumbent is unchanged).
    pub fn set_incumbent(&self, base: &str, version: u32) -> bool {
        let mut versions = self.versions.write().unwrap();
        match versions.get_mut(base) {
            Some(set) if set.versions.contains(&version) => {
                set.incumbent = version;
                true
            }
            _ => false,
        }
    }

    /// Incumbent version of `base`, if it has registered versions.
    pub fn incumbent(&self, base: &str) -> Option<u32> {
        self.versions.read().unwrap().get(base).map(|s| s.incumbent)
    }

    /// Registered versions of `base`, ascending.
    pub fn versions(&self, base: &str) -> Vec<u32> {
        self.versions
            .read()
            .unwrap()
            .get(base)
            .map(|s| s.versions.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Resolve the serving name a cold pod should boot with: a base name
    /// with registered versions maps to its *current* incumbent's
    /// versioned name; explicit versioned names and unversioned models
    /// pass through. This is the boot-profile retag hook — after a
    /// promote, replacement pods of the same group boot the new version
    /// without a kill+respawn of the group.
    pub fn serving_name(&self, name: &str) -> String {
        let (base, version) = split_version(name);
        if version.is_some() {
            return name.to_string();
        }
        match self.incumbent(base) {
            Some(v) => versioned_name(base, v),
            None => name.to_string(),
        }
    }

    /// Hot-load a model from the repository directory at runtime
    /// (Triton's explicit `load` model-control call). Pass a runtime to
    /// compile engines, or `None` for metadata-only. Replaces any
    /// previously loaded entry of the same name (in-flight requests keep
    /// their `Arc` to the old entry).
    pub fn load_model_dynamic(
        &self,
        runtime: Option<&PjrtRuntime>,
        name: &str,
    ) -> Result<Arc<ModelEntry>> {
        let entry = Arc::new(
            Self::load_model(runtime, &self.root, name)
                .with_context(|| format!("hot-loading model '{name}'"))?,
        );
        self.models
            .write()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Hot-unload a model (Triton's `unload`). Requests for it get
    /// `ModelNotFound` from then on; in-flight batches finish on their
    /// existing `Arc`. Returns true if the model was loaded.
    pub fn unload(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    fn load_model(runtime: Option<&PjrtRuntime>, root: &Path, name: &str) -> Result<ModelEntry> {
        let dir = root.join(name);
        if !dir.is_dir() {
            bail!(
                "no model directory {} (run `make artifacts`?)",
                dir.display()
            );
        }
        let cfg_text = std::fs::read_to_string(dir.join("config.yaml"))
            .with_context(|| format!("reading {}/config.yaml", dir.display()))?;
        let cfg = yaml::parse(&cfg_text).context("parsing model config.yaml")?;

        let declared = cfg
            .get("name")
            .and_then(|v| v.as_str())
            .context("model config missing 'name'")?;
        if declared != name {
            bail!("config.yaml declares name '{declared}' but directory is '{name}'");
        }
        let input_shape: Vec<usize> = cfg
            .get_path("input.dims")
            .and_then(|v| v.as_seq())
            .context("model config missing input.dims")?
            .iter()
            .map(|d| d.as_i64().map(|x| x as usize).context("bad dim"))
            .collect::<Result<_>>()?;
        let output_dim = cfg
            .get_path("output.dims")
            .and_then(|v| v.as_seq())
            .and_then(|s| s.first())
            .and_then(|v| v.as_i64())
            .context("model config missing output.dims")? as usize;
        let parameters = cfg
            .get("parameters")
            .and_then(|v| v.as_i64())
            .unwrap_or(0) as u64;

        let batch_sizes: Vec<usize> = cfg
            .get("batch_sizes")
            .and_then(|v| v.as_seq())
            .context("model config missing batch_sizes")?
            .iter()
            .map(|v| v.as_i64().map(|x| x as usize).context("bad batch size"))
            .collect::<Result<_>>()?;
        if batch_sizes.is_empty() || batch_sizes.windows(2).any(|w| w[0] >= w[1]) {
            bail!("config.yaml batch_sizes must be non-empty and strictly increasing");
        }

        let engines = match runtime {
            None => None,
            Some(rt) => {
                let engines = EngineSet::load(rt, &dir, name)?;
                // Cross-check declared batch sizes against compiled artifacts.
                let actual = engines.batch_sizes();
                if batch_sizes != actual {
                    bail!(
                        "config.yaml batch_sizes {:?} != compiled artifacts {:?}",
                        batch_sizes,
                        actual
                    );
                }
                Some(engines)
            }
        };

        Ok(ModelEntry {
            name: name.to_string(),
            input_shape,
            output_dim,
            parameters,
            batch_sizes,
            engines,
        })
    }

    /// Look up a model.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// Served model names.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Repository root path.
    pub fn root(&self) -> &Path {
        &self.root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    #[test]
    #[cfg_attr(
        not(feature = "pjrt"),
        ignore = "needs compiled PJRT engines: build with --features pjrt after `make artifacts`"
    )]
    fn loads_particlenet() {
        let rt = PjrtRuntime::cpu().unwrap();
        let repo =
            ModelRepository::load(&rt, &artifacts_root(), &["particlenet".into()]).unwrap();
        let m = repo.get("particlenet").unwrap();
        assert_eq!(m.input_shape, vec![64, 7]);
        assert_eq!(m.output_dim, 2);
        assert_eq!(m.engines.as_ref().unwrap().batch_sizes(), vec![1, 2, 4, 8, 16]);
        assert!(m.parameters > 10_000);
        assert!(repo.get("nope").is_none());
    }

    #[test]
    fn validate_input_shapes() {
        let repo =
            ModelRepository::load_metadata(&artifacts_root(), &["icecube_cnn".into()]).unwrap();
        let m = repo.get("icecube_cnn").unwrap();
        assert!(m.validate_input(&[4, 16, 16, 3]).is_ok());
        assert!(m.validate_input(&[0, 16, 16, 3]).is_err()); // empty batch
        assert!(m.validate_input(&[4, 16, 16]).is_err()); // wrong rank
        assert!(m.validate_input(&[4, 8, 16, 3]).is_err()); // wrong dims
    }

    #[test]
    fn metadata_only_load_skips_compilation() {
        let repo = ModelRepository::load_metadata(
            &artifacts_root(),
            &["particlenet".into(), "cms_transformer".into()],
        )
        .unwrap();
        let m = repo.get("particlenet").unwrap();
        assert!(m.engines.is_none());
        assert_eq!(m.batch_sizes, vec![1, 2, 4, 8, 16]);
        assert_eq!(m.max_batch(), 16);
        assert_eq!(m.output_dim, 2);
        // 4 bytes per f32 parameter
        assert_eq!(m.memory_bytes(), m.parameters * 4);
        assert!(m.memory_bytes() > 40_000);
    }

    #[test]
    fn missing_model_errors() {
        let err = ModelRepository::load_metadata(&artifacts_root(), &["missing_model".into()])
            .unwrap_err();
        assert!(err.to_string().contains("missing_model"));
    }

    #[test]
    fn version_name_roundtrip() {
        assert_eq!(versioned_name("pn", 2), "pn@v2");
        assert_eq!(split_version("pn@v2"), ("pn", Some(2)));
        assert_eq!(split_version("pn"), ("pn", None));
        // malformed suffixes are not versions
        assert_eq!(split_version("pn@vx"), ("pn@vx", None));
        assert_eq!(split_version("pn@v"), ("pn@v", None));
        assert_eq!(split_version("@v1"), ("@v1", None));
        // nested-looking names split on the last marker
        assert_eq!(split_version("a@v1@v2"), ("a@v1", Some(2)));
    }

    #[test]
    fn version_registry_lifecycle() {
        let repo =
            ModelRepository::load_metadata(&artifacts_root(), &["particlenet".into()]).unwrap();
        assert!(repo.incumbent("particlenet").is_none());
        assert_eq!(repo.serving_name("particlenet"), "particlenet");

        // registering versions serves them under base@vN, sharing the entry
        repo.register_version("particlenet", 1).unwrap();
        repo.register_version("particlenet", 2).unwrap();
        assert_eq!(repo.versions("particlenet"), vec![1, 2]);
        assert_eq!(repo.incumbent("particlenet"), Some(1));
        let base = repo.get("particlenet").unwrap();
        let v2 = repo.get("particlenet@v2").unwrap();
        assert!(Arc::ptr_eq(&base, &v2), "versions share the base entry");

        // boot-profile retag follows the incumbent
        assert_eq!(repo.serving_name("particlenet"), "particlenet@v1");
        assert!(repo.set_incumbent("particlenet", 2));
        assert_eq!(repo.serving_name("particlenet"), "particlenet@v2");
        // explicit versioned names pass through unchanged
        assert_eq!(repo.serving_name("particlenet@v1"), "particlenet@v1");

        // unknown versions / bases are rejected
        assert!(!repo.set_incumbent("particlenet", 9));
        assert_eq!(repo.incumbent("particlenet"), Some(2));
        assert!(!repo.set_incumbent("nope", 1));
        assert!(repo.register_version("nope", 1).is_err());
    }
}
